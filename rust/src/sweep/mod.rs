//! The sweep engine: batched execution of many simulations.
//!
//! The paper's headline claims (Figs. 2–4: ~80 % communication savings at
//! large n, robustness across the attack zoo, contraction at the
//! theoretical rate) are established by *sweeping* n, f, σ, attacks and
//! aggregators — not by any single run. This module turns that sweep
//! surface into a first-class subsystem:
//!
//! * [`SweepGrid`] declares a cross-product of [`ExperimentConfig`]
//!   variations over typed axes — `(n, f, b)` triples (varied jointly
//!   because validity couples them), σ, d, model, attack, aggregator,
//!   echo on/off, radio channel (the loss axis), and seed;
//! * [`SweepGrid::run`] executes every cell across the shared scoped
//!   thread pool ([`crate::par`]). Each cell is an independent
//!   `Simulation` whose RNG streams are derived solely from its own
//!   config (pre-split per cell by construction — no RNG is shared across
//!   cells), so the schedule across threads can never change a bit of any
//!   result;
//! * results collect into a typed [`SweepReport`] (per-cell echo rate,
//!   comm savings, final distance, contraction estimate, phase timings)
//!   with JSON/CSV serialization via [`crate::metrics`]. Scalar outcomes
//!   come from the trace pipeline's online summary ([`crate::trace`]),
//!   and the rounds retained by the cell's
//!   [`crate::trace::TracePolicy`] are serialized as the cell's `trace`
//!   trajectory (empty under `Summary`, the policy most presets pin) —
//!   what [`crate::figures::curves`] renders as true convergence curves.
//!
//! **Determinism contract.** [`SweepReport::to_json`] excludes wall-clock
//! timings, and cells are ordered by grid position — so the rendered
//! report is **byte-identical at any thread count** for the same grid
//! (pinned by `rust/tests/sweep.rs`). Timings are still recorded per cell
//! and rendered by [`SweepReport::to_json_with_timings`], which the bench
//! binaries use for the CI `BENCH_*.json` perf artifacts.
//!
//! Cell-level parallelism composes with the round engine's inner
//! parallelism (`base.threads`), but the presets pin inner threads to 1:
//! for a grid of many small simulations, one cell per core is the right
//! decomposition.
//!
//! The serialized schema (field meanings, grid ordering, determinism
//! guarantees, artifact naming) is documented in `docs/bench-schema.md`;
//! the figure/ablation layer ([`crate::figures`]) consumes these reports
//! to render the paper's Figures 2–4.

use crate::byzantine::AttackKind;
use crate::config::{ExperimentConfig, ModelKind};
use crate::coordinator::Aggregator;
use crate::fec::Recovery;
use crate::metrics::{CsvTable, Json};
use crate::radio::ChannelModel;
use crate::sim::{ChannelTotals, PhaseTimings, Simulation};
use crate::trace::{RoundEvent, TracePolicy};
use crate::wire::WireCodec;
use std::io;
use std::path::Path;

pub use crate::trace::empirical_rho;

/// Scale profile for a sweep: `Full` is the paper-figure size, `Smoke` a
/// seconds-not-minutes reduction used by CI's `bench-smoke` job and
/// `scripts/verify.sh --smoke-bench`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepProfile {
    Full,
    Smoke,
}

impl SweepProfile {
    pub fn name(self) -> &'static str {
        match self {
            SweepProfile::Full => "full",
            SweepProfile::Smoke => "smoke",
        }
    }

    pub fn parse(s: &str) -> Option<SweepProfile> {
        Some(match s {
            "full" => SweepProfile::Full,
            "smoke" | "quick" | "ci" => SweepProfile::Smoke,
            _ => return None,
        })
    }
}

/// Resolve the profile for a bench binary: a `--profile smoke|full` CLI
/// argument wins (a malformed one is a hard error — silently falling back
/// to the full paper-size grid would burn minutes on a typo); otherwise
/// `ECHO_CGC_BENCH_QUICK=1` (the harness's existing quick-mode switch)
/// selects smoke; otherwise full.
pub fn bench_profile() -> SweepProfile {
    let args: Vec<String> = std::env::args().collect();
    for (i, a) in args.iter().enumerate() {
        let value = if a == "--profile" {
            Some(args.get(i + 1).map(String::as_str).unwrap_or_else(|| {
                eprintln!("--profile needs a value (smoke|full)");
                std::process::exit(2);
            }))
        } else {
            a.strip_prefix("--profile=")
        };
        if let Some(v) = value {
            return SweepProfile::parse(v).unwrap_or_else(|| {
                eprintln!("unknown profile '{v}' (expected smoke|full)");
                std::process::exit(2);
            });
        }
    }
    let quick = std::env::var("ECHO_CGC_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    if quick {
        SweepProfile::Smoke
    } else {
        SweepProfile::Full
    }
}

/// One thread per available core — the default cell-level parallelism for
/// bench binaries (`ExperimentConfig::effective_threads` with `threads=0`
/// resolves through the same [`crate::par::available_threads`] policy).
pub fn auto_threads() -> usize {
    crate::par::available_threads()
}

/// A declarative grid of experiment variations. Empty axes fall back to
/// the base config's value; non-empty axes multiply into a cross-product
/// enumerated in a fixed nesting order (outermost → innermost): `nfb`,
/// `models`, `sigmas`, `dims`, `attacks`, `aggregators`, `echo`,
/// `channels`, `recoveries`, `codecs`, `churns`, `stragglers`, `alphas`,
/// `seeds`.
#[derive(Clone, Debug)]
pub struct SweepGrid {
    pub name: String,
    pub profile: SweepProfile,
    pub base: ExperimentConfig,
    /// Joint `(n, f, b)` axis — varied together because `f < n/2` and
    /// `b ≤ f` couple them.
    pub nfb: Vec<(usize, usize, usize)>,
    pub models: Vec<ModelKind>,
    pub sigmas: Vec<f64>,
    pub dims: Vec<usize>,
    pub attacks: Vec<AttackKind>,
    pub aggregators: Vec<Aggregator>,
    pub echo: Vec<bool>,
    /// The loss axis: radio channel models
    /// ([`crate::radio::ChannelModel`]).
    pub channels: Vec<ChannelModel>,
    /// The uplink loss-recovery axis ([`crate::fec::Recovery`]): ARQ (the
    /// pre-FEC discipline), Reed–Solomon shard spreading, or hybrid.
    /// Nested inside `channels` so each loss rate compares disciplines.
    pub recoveries: Vec<Recovery>,
    /// The gradient wire-codec axis ([`crate::wire::WireCodec`]): lossy
    /// uplink/downlink re-encodings traded against convergence. Nested
    /// inside `recoveries` so each discipline compares codecs under
    /// identical channel draws.
    pub codecs: Vec<WireCodec>,
    /// The membership-churn axis: per-round probability that a worker is
    /// absent (epoch-keyed roster; `0.0` = the fixed-membership default).
    pub churns: Vec<f64>,
    /// The straggler axis: per-round probability that a present honest
    /// worker misses the TDMA deadline (scored `Lost`, never exposed).
    pub stragglers: Vec<f64>,
    /// The heterogeneity axis: Dirichlet concentration for non-IID data
    /// shards (`None` = the IID default; smaller α = more skew).
    pub alphas: Vec<Option<f64>>,
    pub seeds: Vec<u64>,
}

impl SweepGrid {
    pub fn new(name: &str, base: ExperimentConfig) -> SweepGrid {
        SweepGrid {
            name: name.to_string(),
            profile: SweepProfile::Full,
            base,
            nfb: Vec::new(),
            models: Vec::new(),
            sigmas: Vec::new(),
            dims: Vec::new(),
            attacks: Vec::new(),
            aggregators: Vec::new(),
            echo: Vec::new(),
            channels: Vec::new(),
            recoveries: Vec::new(),
            codecs: Vec::new(),
            churns: Vec::new(),
            stragglers: Vec::new(),
            alphas: Vec::new(),
            seeds: Vec::new(),
        }
    }

    /// Materialize the cross-product as concrete configs, in grid order.
    pub fn cells(&self) -> Vec<ExperimentConfig> {
        fn axis<T: Copy>(vals: &[T], base: T) -> Vec<T> {
            if vals.is_empty() {
                vec![base]
            } else {
                vals.to_vec()
            }
        }
        let nfb = axis(&self.nfb, (self.base.n, self.base.f, self.base.b));
        let models = axis(&self.models, self.base.model);
        let sigmas = axis(&self.sigmas, self.base.sigma);
        let dims = axis(&self.dims, self.base.d);
        let attacks = axis(&self.attacks, self.base.attack);
        let aggs = axis(&self.aggregators, self.base.aggregator);
        let echoes = axis(&self.echo, self.base.echo_enabled);
        let channels = axis(&self.channels, self.base.channel);
        let recoveries = axis(&self.recoveries, self.base.recovery);
        let codecs = axis(&self.codecs, self.base.codec);
        let churns = axis(&self.churns, self.base.churn);
        let stragglers = axis(&self.stragglers, self.base.straggler);
        let alphas = axis(&self.alphas, self.base.alpha);
        let seeds = axis(&self.seeds, self.base.seed);
        let mut out = Vec::new();
        for &(n, f, b) in &nfb {
            for &model in &models {
                for &sigma in &sigmas {
                    for &d in &dims {
                        for &attack in &attacks {
                            for &agg in &aggs {
                                for &echo in &echoes {
                                    for &channel in &channels {
                                        for &recovery in &recoveries {
                                            for &codec in &codecs {
                                                for &churn in &churns {
                                                    for &straggler in &stragglers {
                                                        for &alpha in &alphas {
                                                            for &seed in &seeds {
                                                                let mut cfg =
                                                                    self.base.clone();
                                                                cfg.n = n;
                                                                cfg.f = f;
                                                                cfg.b = b;
                                                                cfg.model = model;
                                                                cfg.sigma = sigma;
                                                                cfg.d = d;
                                                                cfg.attack = attack;
                                                                cfg.aggregator = agg;
                                                                cfg.echo_enabled = echo;
                                                                cfg.channel = channel;
                                                                cfg.recovery = recovery;
                                                                cfg.codec = codec;
                                                                cfg.churn = churn;
                                                                cfg.straggler = straggler;
                                                                cfg.alpha = alpha;
                                                                cfg.seed = seed;
                                                                out.push(cfg);
                                                            }
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of cells the grid will execute (derived from [`Self::cells`]
    /// so it can never drift from the enumeration when axes are added).
    pub fn len(&self) -> usize {
        self.cells().len()
    }

    pub fn is_empty(&self) -> bool {
        false // an all-empty grid still yields the single base cell
    }

    /// Execute every cell, fanning the simulations across up to `threads`
    /// scoped threads pulling from a shared work queue (dynamic balancing:
    /// grids enumerate n ascending, so contiguous chunking would pile the
    /// expensive large-n tail onto the last thread). A cell whose config
    /// fails to build is recorded in the report (`error: Some(..)`) rather
    /// than aborting the sweep, so a partially-invalid grid still yields a
    /// deterministic report.
    pub fn run(&self, threads: usize) -> SweepReport {
        let mut jobs: Vec<(ExperimentConfig, Option<SweepCell>)> =
            self.cells().into_iter().map(|cfg| (cfg, None)).collect();
        crate::par::scoped_for_each_dynamic(&mut jobs, threads, |(cfg, out)| {
            *out = Some(run_cell(cfg));
        });
        let mut cells = Vec::with_capacity(jobs.len());
        for (i, (_, cell)) in jobs.into_iter().enumerate() {
            let mut cell = cell.expect("every cell executes");
            cell.index = i;
            cells.push(cell);
        }
        SweepReport { name: self.name.clone(), profile: self.profile, cells }
    }
}

/// One executed grid cell: the config coordinates that identify it plus
/// the measured outcomes. Wall-clock phase timings ride along but are
/// excluded from the deterministic JSON (see [`SweepReport::to_json`]).
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub index: usize,
    pub label: String,
    pub n: usize,
    pub f: usize,
    pub b: usize,
    pub d: usize,
    pub model: &'static str,
    pub attack: &'static str,
    pub aggregator: &'static str,
    pub sigma: f64,
    pub seed: u64,
    pub rounds: usize,
    pub echo_enabled: bool,
    /// The radio channel the cell ran over (the `loss` axis coordinate).
    pub channel: ChannelModel,
    /// The uplink recovery discipline the cell ran under (the `recovery`
    /// axis coordinate; serialized only when not the ARQ default).
    pub recovery: Recovery,
    /// The gradient wire codec the cell ran under (the `codec` axis
    /// coordinate; serialized only when not the f64 identity default).
    pub codec: WireCodec,
    /// Per-round absence probability the cell ran under (the `churn` axis
    /// coordinate; serialized only when non-zero).
    pub churn: f64,
    /// Per-round straggler probability the cell ran under (serialized
    /// only when non-zero).
    pub straggler: f64,
    /// Dirichlet concentration of the cell's non-IID shards (`None` =
    /// IID; serialized only when set).
    pub alpha: Option<f64>,
    /// Cumulative worker-rounds absent from the roster (serialized only
    /// for churned cells).
    pub absent: u64,
    /// Cumulative missed-deadline slots by present honest workers
    /// (serialized only for straggler cells).
    pub late: u64,
    pub echo_rate: f64,
    pub comm_savings: f64,
    pub final_loss: f64,
    pub final_dist_sq: Option<f64>,
    pub uplink_bits_total: u64,
    pub exposed: usize,
    /// Cumulative channel casualties (all 0 under a lossless channel;
    /// serialized only for lossy cells, which keeps lossless reports
    /// byte-identical to pre-channel artifacts).
    pub channel_totals: ChannelTotals,
    pub empirical_rho: Option<f64>,
    pub theory_rho: Option<f64>,
    /// Retention policy the cell ran under (identity, not a measurement).
    pub trace_policy: TracePolicy,
    /// Per-round trajectory retained by the trace sink (empty under
    /// `TracePolicy::Summary`), serialized as parallel arrays.
    pub trace: Vec<RoundEvent>,
    pub timings: PhaseTimings,
    pub error: Option<String>,
}

impl SweepCell {
    /// Measured uplink bits per round.
    pub fn bits_per_round(&self) -> u64 {
        if self.rounds == 0 {
            0
        } else {
            self.uplink_bits_total / self.rounds as u64
        }
    }

    fn to_json(&self, include_timings: bool) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let mut pairs = vec![
            ("index", Json::Num(self.index as f64)),
            ("label", Json::Str(self.label.clone())),
            ("n", Json::Num(self.n as f64)),
            ("f", Json::Num(self.f as f64)),
            ("b", Json::Num(self.b as f64)),
            ("d", Json::Num(self.d as f64)),
            ("model", Json::Str(self.model.to_string())),
            ("attack", Json::Str(self.attack.to_string())),
            ("aggregator", Json::Str(self.aggregator.to_string())),
            ("sigma", Json::Num(self.sigma)),
            ("seed", Json::Num(self.seed as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("echo_enabled", Json::Bool(self.echo_enabled)),
            ("echo_rate", Json::Num(self.echo_rate)),
            ("comm_savings", Json::Num(self.comm_savings)),
            ("final_loss", Json::Num(self.final_loss)),
            ("final_dist_sq", opt(self.final_dist_sq)),
            ("uplink_bits_total", Json::Num(self.uplink_bits_total as f64)),
            ("exposed", Json::Num(self.exposed as f64)),
            ("empirical_rho", opt(self.empirical_rho)),
            ("theory_rho", opt(self.theory_rho)),
            ("trace_policy", Json::Str(self.trace_policy.label())),
            (
                "trace",
                if self.trace.is_empty() { Json::Null } else { trace_json(&self.trace) },
            ),
            (
                "error",
                self.error.as_ref().map(|e| Json::Str(e.clone())).unwrap_or(Json::Null),
            ),
        ];
        // Channel identity + casualty totals ride along only for lossy
        // cells: a lossless cell (perfect, bernoulli=0.0, zero-loss GE)
        // serializes the exact pre-channel schema, byte for byte — the
        // backward-compatibility contract pinned by rust/tests/channel.rs.
        if !self.channel.is_lossless() {
            pairs.push(("channel", Json::Str(self.channel.label())));
            pairs.push(("dropped_frames", Json::Num(self.channel_totals.dropped_frames as f64)));
            pairs.push(("retransmits", Json::Num(self.channel_totals.retransmits as f64)));
            pairs.push(("fallbacks", Json::Num(self.channel_totals.fallbacks as f64)));
            pairs.push(("lost_slots", Json::Num(self.channel_totals.lost_slots as f64)));
        }
        // Same contract for the recovery axis: only non-ARQ cells carry
        // the discipline and its counters, so every `recovery=arq` cell —
        // lossless or lossy — serializes the exact pre-FEC schema.
        if self.recovery != Recovery::Arq {
            pairs.push(("recovery", Json::Str(self.recovery.name().to_string())));
            pairs.push(("fec_recoveries", Json::Num(self.channel_totals.fec_recoveries as f64)));
            pairs.push(("equivocations", Json::Num(self.channel_totals.equivocations as f64)));
        }
        // And for the codec axis: `codec=f64` is the identity encode, so
        // default cells serialize the exact pre-codec schema byte for byte.
        if self.codec != WireCodec::F64 {
            pairs.push(("codec", Json::Str(self.codec.name())));
        }
        // Membership axes follow the same contract: a churn-free,
        // straggler-free, IID cell serializes the exact pre-churn schema
        // byte for byte.
        if self.churn != 0.0 {
            pairs.push(("churn", Json::Num(self.churn)));
            pairs.push(("absent", Json::Num(self.absent as f64)));
        }
        if self.straggler != 0.0 {
            pairs.push(("straggler", Json::Num(self.straggler)));
            pairs.push(("late", Json::Num(self.late as f64)));
        }
        if let Some(a) = self.alpha {
            pairs.push(("alpha", Json::Num(a)));
        }
        if include_timings {
            pairs.push(("grad_ns", Json::Num(self.timings.grad_ns as f64)));
            pairs.push(("comm_ns", Json::Num(self.timings.comm_ns as f64)));
            pairs.push(("agg_ns", Json::Num(self.timings.agg_ns as f64)));
        }
        Json::obj(pairs)
    }
}

/// The typed result of a sweep, in grid order.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    pub profile: SweepProfile,
    pub cells: Vec<SweepCell>,
}

impl SweepReport {
    /// Cells that failed to build.
    pub fn failed(&self) -> Vec<&SweepCell> {
        self.cells.iter().filter(|c| c.error.is_some()).collect()
    }

    fn json(&self, include_timings: bool) -> Json {
        Json::obj(vec![
            ("sweep", Json::Str(self.name.clone())),
            ("profile", Json::Str(self.profile.name().to_string())),
            ("cell_count", Json::Num(self.cells.len() as f64)),
            (
                "cells",
                Json::Arr(self.cells.iter().map(|c| c.to_json(include_timings)).collect()),
            ),
        ])
    }

    /// Deterministic rendering: **no wall-clock fields**, cells in grid
    /// order — byte-identical at any thread count for the same grid.
    pub fn to_json(&self) -> Json {
        self.json(false)
    }

    /// Rendering with per-cell phase timings — the CI `BENCH_*.json`
    /// perf-trajectory artifact.
    pub fn to_json_with_timings(&self) -> Json {
        self.json(true)
    }

    pub fn write_json<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        self.to_json().write_file_pretty(path)
    }

    pub fn write_json_with_timings<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        self.to_json_with_timings().write_file_pretty(path)
    }

    /// Flat CSV rendering (one row per cell, fixed schema). The recovery
    /// columns appear only when some cell ran a non-ARQ discipline, so
    /// pure-ARQ reports render the exact pre-FEC CSV bytes.
    pub fn csv(&self) -> CsvTable {
        let with_recovery = self.cells.iter().any(|c| c.recovery != Recovery::Arq);
        let with_codec = self.cells.iter().any(|c| c.codec != WireCodec::F64);
        let with_churn = self.cells.iter().any(|c| c.churn != 0.0);
        let with_straggler = self.cells.iter().any(|c| c.straggler != 0.0);
        let with_alpha = self.cells.iter().any(|c| c.alpha.is_some());
        let mut header = vec![
            "index",
            "label",
            "n",
            "f",
            "b",
            "d",
            "model",
            "attack",
            "aggregator",
            "sigma",
            "seed",
            "rounds",
            "echo_enabled",
            "channel",
            "echo_rate",
            "comm_savings",
            "final_loss",
            "final_dist_sq",
            "uplink_bits_total",
            "exposed",
            "dropped_frames",
            "retransmits",
            "fallbacks",
            "lost_slots",
            "empirical_rho",
            "theory_rho",
            "error",
        ];
        if with_recovery {
            let i = header.iter().position(|&h| h == "empirical_rho").unwrap();
            header.splice(i..i, ["recovery", "fec_recoveries", "equivocations"]);
        }
        // The codec column splices immediately before `empirical_rho` too
        // (after any recovery columns), so pure-f64 reports keep the
        // pre-codec CSV bytes.
        if with_codec {
            let i = header.iter().position(|&h| h == "empirical_rho").unwrap();
            header.splice(i..i, ["codec"]);
        }
        // Membership columns splice before `empirical_rho` as well (after
        // any codec column), so churn-free reports keep the pre-churn CSV
        // bytes.
        if with_churn {
            let i = header.iter().position(|&h| h == "empirical_rho").unwrap();
            header.splice(i..i, ["churn", "absent"]);
        }
        if with_straggler {
            let i = header.iter().position(|&h| h == "empirical_rho").unwrap();
            header.splice(i..i, ["straggler", "late"]);
        }
        if with_alpha {
            let i = header.iter().position(|&h| h == "empirical_rho").unwrap();
            header.splice(i..i, ["alpha"]);
        }
        let mut t = CsvTable::new(&header);
        let opt = |v: Option<f64>| v.map(|x| format!("{x}")).unwrap_or_default();
        for c in &self.cells {
            let mut row = vec![
                format!("{}", c.index),
                c.label.clone(),
                format!("{}", c.n),
                format!("{}", c.f),
                format!("{}", c.b),
                format!("{}", c.d),
                c.model.to_string(),
                c.attack.to_string(),
                c.aggregator.to_string(),
                format!("{}", c.sigma),
                format!("{}", c.seed),
                format!("{}", c.rounds),
                format!("{}", c.echo_enabled),
                c.channel.tag(),
                format!("{}", c.echo_rate),
                format!("{}", c.comm_savings),
                format!("{}", c.final_loss),
                opt(c.final_dist_sq),
                format!("{}", c.uplink_bits_total),
                format!("{}", c.exposed),
                format!("{}", c.channel_totals.dropped_frames),
                format!("{}", c.channel_totals.retransmits),
                format!("{}", c.channel_totals.fallbacks),
                format!("{}", c.channel_totals.lost_slots),
            ];
            if with_recovery {
                row.push(c.recovery.name().to_string());
                row.push(format!("{}", c.channel_totals.fec_recoveries));
                row.push(format!("{}", c.channel_totals.equivocations));
            }
            if with_codec {
                row.push(c.codec.name());
            }
            if with_churn {
                row.push(format!("{}", c.churn));
                row.push(format!("{}", c.absent));
            }
            if with_straggler {
                row.push(format!("{}", c.straggler));
                row.push(format!("{}", c.late));
            }
            if with_alpha {
                row.push(c.alpha.map(|a| format!("{a}")).unwrap_or_default());
            }
            row.push(opt(c.empirical_rho));
            row.push(opt(c.theory_rho));
            row.push(c.error.clone().unwrap_or_default());
            t.push_row_mixed(row);
        }
        t
    }
}

/// Serialize retained per-round events as parallel arrays — compact, and
/// column-oriented like the figure layer reads them. Missing `dist_sq`
/// entries render as `null` (as do non-finite values, per the JSON
/// writer's contract). The channel-casualty columns (`dropped`,
/// `retransmits`, `fallbacks`) appear only when any round recorded one —
/// lossless traces keep the exact pre-channel schema.
fn trace_json(events: &[RoundEvent]) -> Json {
    let num = |f: fn(&RoundEvent) -> f64| -> Json {
        Json::Arr(events.iter().map(|e| Json::Num(f(e))).collect())
    };
    let dist = Json::Arr(
        events.iter().map(|e| e.dist_sq.map(Json::Num).unwrap_or(Json::Null)).collect(),
    );
    let mut pairs = vec![
        ("round", num(|e| e.round as f64)),
        ("loss", num(|e| e.loss)),
        ("dist_sq", dist),
        ("uplink_bits", num(|e| e.uplink_bits as f64)),
        ("echo", num(|e| e.echo_count as f64)),
        ("raw", num(|e| e.raw_count as f64)),
        ("exposed", num(|e| e.exposed_cum as f64)),
        ("clipped", num(|e| e.clipped as f64)),
    ];
    let lossy =
        events.iter().any(|e| e.dropped_frames > 0 || e.retransmits > 0 || e.fallbacks > 0);
    if lossy {
        pairs.push(("dropped", num(|e| e.dropped_frames as f64)));
        pairs.push(("retransmits", num(|e| e.retransmits as f64)));
        pairs.push(("fallbacks", num(|e| e.fallbacks as f64)));
    }
    // Membership columns appear only when some round saw churn or a
    // missed deadline — fixed-membership traces keep the prior schema.
    if events.iter().any(|e| e.absent > 0 || e.late > 0) {
        pairs.push(("absent", num(|e| e.absent as f64)));
        pairs.push(("late", num(|e| e.late as f64)));
    }
    Json::obj(pairs)
}

/// Build + run one cell; build failures become report rows, not panics.
fn run_cell(cfg: &ExperimentConfig) -> SweepCell {
    // `run_tag()` covers model/n/f/attack; extend it with the remaining
    // swept axes so every cell in a grid gets a distinct label. The
    // channel suffix appears only for lossy cells (label stability for
    // the pre-channel artifact names).
    let label = format!(
        "{}_{}_sigma{}_d{}_seed{}{}{}{}{}{}{}{}",
        cfg.run_tag(),
        cfg.aggregator.name(),
        cfg.sigma,
        cfg.d,
        cfg.seed,
        if cfg.echo_enabled { String::new() } else { "_noecho".to_string() },
        if cfg.channel.is_lossless() {
            String::new()
        } else {
            format!("_{}", cfg.channel.tag())
        },
        // ARQ cells keep their pre-FEC labels (artifact-name stability).
        if cfg.recovery == Recovery::Arq {
            String::new()
        } else {
            format!("_{}", cfg.recovery.name())
        },
        // f64 cells likewise keep their pre-codec labels.
        if cfg.codec == WireCodec::F64 {
            String::new()
        } else {
            format!("_{}", cfg.codec.name())
        },
        // Fixed-membership IID cells keep their pre-churn labels.
        if cfg.churn == 0.0 { String::new() } else { format!("_churn{}", cfg.churn) },
        if cfg.straggler == 0.0 {
            String::new()
        } else {
            format!("_strag{}", cfg.straggler)
        },
        match cfg.alpha {
            None => String::new(),
            Some(a) => format!("_a{a}"),
        }
    );
    let mut cell = SweepCell {
        index: 0,
        label,
        n: cfg.n,
        f: cfg.f,
        b: cfg.b,
        d: cfg.d,
        model: cfg.model.name(),
        attack: cfg.attack.name(),
        aggregator: cfg.aggregator.name(),
        sigma: cfg.sigma,
        seed: cfg.seed,
        rounds: cfg.rounds,
        echo_enabled: cfg.echo_enabled,
        channel: cfg.channel,
        recovery: cfg.recovery,
        codec: cfg.codec,
        churn: cfg.churn,
        straggler: cfg.straggler,
        alpha: cfg.alpha,
        absent: 0,
        late: 0,
        echo_rate: f64::NAN,
        comm_savings: f64::NAN,
        final_loss: f64::NAN,
        final_dist_sq: None,
        uplink_bits_total: 0,
        exposed: 0,
        channel_totals: ChannelTotals::default(),
        empirical_rho: None,
        theory_rho: None,
        trace_policy: cfg.trace,
        trace: Vec::new(),
        timings: PhaseTimings::default(),
        error: None,
    };
    let mut sim = match Simulation::build(cfg) {
        Ok(s) => s,
        Err(e) => {
            cell.error = Some(e);
            return cell;
        }
    };
    sim.run_silent();
    // Scalars come from the sink's online summary, so they are identical
    // under every retention policy — no re-derivation from records.
    let summary = *sim.trace().summary();
    cell.d = sim.model().dim();
    cell.echo_rate = sim.echo_rate();
    cell.comm_savings = sim.comm_savings();
    cell.final_loss = summary.final_loss;
    cell.final_dist_sq = sim.final_dist_sq();
    cell.uplink_bits_total = sim.radio().meter.total_uplink();
    cell.exposed = sim.server().exposed().len();
    cell.channel_totals = sim.channel_totals();
    let (absent, late) = sim.membership_totals();
    cell.absent = absent;
    cell.late = late;
    cell.empirical_rho = summary.fit.rho();
    cell.theory_rho = Some(sim.realized_theory().rho(sim.eta()));
    cell.trace = sim.trace().points();
    cell.timings = sim.timings;
    cell
}

/// Canonical grids: the bench binaries and `echo-cgc sweep` share these,
/// so a figure regenerated locally and one produced by CI come from the
/// same declaration.
pub mod presets {
    use super::*;

    /// Attack zoo × aggregation rules (benches/attack_matrix.rs; the
    /// qualitative Fig. 3 claim — Echo-CGC converges under every attack).
    pub fn attack_matrix(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 15;
        base.f = 1;
        base.b = 1;
        base.d = 50;
        base.sigma = 0.05;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.rounds = match profile {
            SweepProfile::Full => 250,
            SweepProfile::Smoke => 60,
        };
        let mut grid = SweepGrid::new("attack_matrix", base);
        grid.profile = profile;
        grid.attacks = AttackKind::all().to_vec();
        grid.aggregators = Aggregator::all().to_vec();
        grid
    }

    /// Echo-CGC vs GV-CGC (echo disabled — the raw-broadcast ancestor):
    /// same robustness, full bit cost.
    pub fn gv_baseline(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 15;
        base.f = 1;
        base.b = 1;
        base.d = 50;
        base.sigma = 0.05;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.attack = AttackKind::Omniscient;
        base.rounds = match profile {
            SweepProfile::Full => 250,
            SweepProfile::Smoke => 60,
        };
        let mut grid = SweepGrid::new("gv_baseline", base);
        grid.profile = profile;
        grid.echo = vec![true, false];
        grid
    }

    /// Measured communication savings across (n, f) × σ (the §4.3
    /// headline numbers; benches/comm_savings.rs).
    pub fn comm_savings(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.d = 200;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.rounds = match profile {
            SweepProfile::Full => 40,
            SweepProfile::Smoke => 10,
        };
        let mut grid = SweepGrid::new("comm_savings", base);
        grid.profile = profile;
        grid.nfb = match profile {
            SweepProfile::Full => vec![(20, 2, 2), (50, 5, 5), (100, 10, 10)],
            SweepProfile::Smoke => vec![(20, 2, 2), (50, 5, 5)],
        };
        grid.sigmas = vec![0.05, 0.10];
        grid
    }

    /// Empirical vs theoretical contraction across (n, f) × σ × attack
    /// (Theorem 9; benches/convergence.rs). The only preset that carries
    /// trajectories: a bounded every-k trace per cell, so the bench and
    /// `echo-cgc figures --fig curves` can render true error-vs-round
    /// convergence curves instead of final-error bars.
    pub fn convergence(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.d = 60;
        base.threads = 1;
        base.rounds = match profile {
            SweepProfile::Full => 300,
            SweepProfile::Smoke => 80,
        };
        base.trace = match profile {
            SweepProfile::Full => TracePolicy::EveryK { every_k: 4, max_points: 128 },
            SweepProfile::Smoke => TracePolicy::EveryK { every_k: 2, max_points: 64 },
        };
        let mut grid = SweepGrid::new("convergence", base);
        grid.profile = profile;
        grid.nfb = match profile {
            SweepProfile::Full => vec![(12, 1, 1), (24, 2, 2), (48, 4, 4)],
            SweepProfile::Smoke => vec![(12, 1, 1), (24, 2, 2)],
        };
        grid.sigmas = vec![0.02, 0.08];
        grid.attacks =
            vec![AttackKind::Omniscient, AttackKind::LargeNorm, AttackKind::SignFlip];
        grid
    }

    /// Echo rate / comm savings / final error vs. channel loss
    /// probability — the lossy-overhearing scenario family
    /// (`echo-cgc figures --fig loss`, `echo-cgc sweep --grid loss`).
    /// The loss axis is Bernoulli-erasure probabilities (0 = the paper's
    /// reliable broadcast), so the figure's x axis is numeric; bursty
    /// Gilbert–Elliott channels are reachable through `--channel` /
    /// `--axis loss=…` ablations.
    pub fn loss_sweep(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 20;
        base.f = 2;
        base.b = 2;
        base.d = 100;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.attack = AttackKind::Omniscient;
        base.rounds = match profile {
            SweepProfile::Full => 120,
            SweepProfile::Smoke => 40,
        };
        let mut grid = SweepGrid::new("loss", base);
        grid.profile = profile;
        let ps: &[f64] = match profile {
            SweepProfile::Full => &[0.0, 0.05, 0.1, 0.2, 0.3, 0.4],
            SweepProfile::Smoke => &[0.0, 0.1, 0.3],
        };
        grid.channels = ps.iter().map(|&p| ChannelModel::Bernoulli { p }).collect();
        grid.sigmas = vec![0.05, 0.10];
        grid
    }

    /// ARQ vs FEC vs hybrid uplink recovery across the loss axis
    /// (`echo-cgc figures --fig loss-recovery`, `echo-cgc sweep --grid
    /// loss-recovery`): delivered bits and final error per discipline at
    /// each Bernoulli erasure rate. Same scenario family as
    /// [`loss_sweep`], one σ, with the recovery axis nested inside the
    /// channel axis so each loss rate compares the three disciplines
    /// under identical channel draws.
    pub fn loss_recovery(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 20;
        base.f = 2;
        base.b = 2;
        base.d = 100;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.attack = AttackKind::Omniscient;
        base.rounds = match profile {
            SweepProfile::Full => 120,
            SweepProfile::Smoke => 40,
        };
        let mut grid = SweepGrid::new("loss_recovery", base);
        grid.profile = profile;
        let ps: &[f64] = match profile {
            SweepProfile::Full => &[0.0, 0.05, 0.1, 0.2, 0.3, 0.4],
            SweepProfile::Smoke => &[0.0, 0.1, 0.3],
        };
        grid.channels = ps.iter().map(|&p| ChannelModel::Bernoulli { p }).collect();
        grid.sigmas = vec![0.05];
        grid.recoveries = Recovery::all().to_vec();
        grid
    }

    /// Bits-on-the-air vs final error across the gradient wire codecs
    /// (`echo-cgc figures --fig codec`, `echo-cgc sweep --grid codec`):
    /// every [`WireCodec`] × echo on/off, on a perfect channel so the
    /// only thing varying is the codec itself. The base encoding is
    /// pinned to `f64` precision so the axis spans the full 64 → 32 → 8
    /// → 1 bits-per-coordinate range against the uncompressed baseline.
    pub fn codec_sweep(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 20;
        base.f = 2;
        base.b = 2;
        base.d = 100;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.attack = AttackKind::Omniscient;
        base.precision = crate::wire::Precision::F64;
        base.rounds = match profile {
            SweepProfile::Full => 120,
            SweepProfile::Smoke => 40,
        };
        let mut grid = SweepGrid::new("codec", base);
        grid.profile = profile;
        grid.echo = vec![true, false];
        grid.codecs = WireCodec::sweep_set().to_vec();
        grid
    }

    /// Membership churn × stragglers × non-IID Dirichlet shards on a
    /// logistic-regression task (`echo-cgc figures --fig churn`,
    /// `echo-cgc sweep --grid churn`): the heterogeneity bench. Every
    /// membership draw is a pure hash of `(seed, round, worker)`, so the
    /// grid stays byte-deterministic at any thread count; the all-zero
    /// corner of the grid is the fixed-membership IID baseline and
    /// serializes the exact pre-churn schema.
    pub fn churn_sweep(profile: SweepProfile) -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 12;
        base.f = 1;
        base.b = 1;
        base.d = 10;
        base.model = ModelKind::Logistic;
        base.dataset_m = 200;
        base.batch = 32;
        base.lambda = 0.05;
        base.r = Some(0.3);
        base.eta = Some(0.05);
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        base.rounds = match profile {
            SweepProfile::Full => 120,
            SweepProfile::Smoke => 40,
        };
        let mut grid = SweepGrid::new("churn", base);
        grid.profile = profile;
        grid.churns = match profile {
            SweepProfile::Full => vec![0.0, 0.1, 0.2, 0.3],
            SweepProfile::Smoke => vec![0.0, 0.2],
        };
        grid.stragglers = match profile {
            SweepProfile::Full => vec![0.0, 0.15, 0.3],
            SweepProfile::Smoke => vec![0.0, 0.2],
        };
        grid.alphas = match profile {
            SweepProfile::Full => vec![None, Some(10.0), Some(1.0), Some(0.1)],
            SweepProfile::Smoke => vec![None, Some(0.1)],
        };
        grid
    }

    /// Tiny demonstration grid (`echo-cgc sweep --grid quick`).
    pub fn quick() -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 12;
        base.f = 1;
        base.b = 1;
        base.d = 30;
        base.rounds = 40;
        base.threads = 1;
        base.trace = TracePolicy::Summary;
        let mut grid = SweepGrid::new("quick", base);
        grid.profile = SweepProfile::Smoke;
        grid.attacks = vec![AttackKind::Omniscient, AttackKind::LargeNorm];
        grid.aggregators = vec![Aggregator::CgcSum, Aggregator::Mean];
        grid
    }

    /// Look up a preset by CLI name.
    pub fn by_name(name: &str, profile: SweepProfile) -> Option<SweepGrid> {
        Some(match name {
            "attack-matrix" | "attack_matrix" => attack_matrix(profile),
            "gv-baseline" | "gv_baseline" => gv_baseline(profile),
            "comm-savings" | "comm_savings" => comm_savings(profile),
            "convergence" => convergence(profile),
            "loss" | "loss-sweep" | "loss_sweep" => loss_sweep(profile),
            "loss-recovery" | "loss_recovery" => loss_recovery(profile),
            "codec" | "codecs" => codec_sweep(profile),
            "churn" | "churn-sweep" | "churn_sweep" => churn_sweep(profile),
            "quick" => quick(),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> SweepGrid {
        let mut base = ExperimentConfig::default();
        base.n = 10;
        base.f = 1;
        base.b = 1;
        base.d = 12;
        base.rounds = 8;
        base.seed = 5;
        let mut grid = SweepGrid::new("tiny", base);
        grid.sigmas = vec![0.03, 0.08];
        grid.aggregators = vec![Aggregator::CgcSum, Aggregator::Mean];
        grid
    }

    #[test]
    fn cells_enumerate_the_cross_product_in_grid_order() {
        let grid = tiny_grid();
        let cells = grid.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(grid.len(), 4);
        // sigma is the outer axis relative to aggregator.
        assert_eq!(cells[0].sigma, 0.03);
        assert_eq!(cells[0].aggregator, Aggregator::CgcSum);
        assert_eq!(cells[1].sigma, 0.03);
        assert_eq!(cells[1].aggregator, Aggregator::Mean);
        assert_eq!(cells[2].sigma, 0.08);
        // Untouched axes fall back to the base.
        assert!(cells.iter().all(|c| c.n == 10 && c.d == 12 && c.seed == 5));
    }

    #[test]
    fn empty_axes_yield_the_single_base_cell() {
        let grid = SweepGrid::new("base-only", tiny_grid().base);
        assert_eq!(grid.cells().len(), 1);
        assert_eq!(grid.len(), 1);
        assert!(!grid.is_empty());
    }

    #[test]
    fn report_records_outcomes_per_cell() {
        let report = tiny_grid().run(2);
        assert_eq!(report.cells.len(), 4);
        for (i, c) in report.cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert!(c.error.is_none(), "{:?}", c.error);
            assert!(c.final_loss.is_finite());
            assert!(c.uplink_bits_total > 0);
            assert!((0.0..=1.0).contains(&c.echo_rate));
            assert!(c.theory_rho.is_some());
        }
        // CgcSum vs Mean cells share every coordinate except the rule.
        assert_eq!(report.cells[0].aggregator, "cgc");
        assert_eq!(report.cells[1].aggregator, "mean");
        assert_eq!(report.csv().n_rows(), 4);
    }

    #[test]
    fn deterministic_json_excludes_timings() {
        let report = tiny_grid().run(2);
        let det = report.to_json().to_string();
        let timed = report.to_json_with_timings().to_string();
        assert!(!det.contains("grad_ns"));
        assert!(timed.contains("grad_ns"));
    }

    #[test]
    fn profile_parse_roundtrip() {
        for p in [SweepProfile::Full, SweepProfile::Smoke] {
            assert_eq!(SweepProfile::parse(p.name()), Some(p));
        }
        assert_eq!(SweepProfile::parse("bogus"), None);
    }

    #[test]
    fn presets_resolve_by_name() {
        for name in [
            "attack-matrix",
            "gv-baseline",
            "comm-savings",
            "convergence",
            "loss",
            "loss-recovery",
            "codec",
            "churn",
            "quick",
        ] {
            let grid = presets::by_name(name, SweepProfile::Smoke).unwrap();
            assert!(grid.len() >= 2, "{name} should sweep something");
        }
        assert!(presets::by_name("nope", SweepProfile::Smoke).is_none());
    }

    #[test]
    fn recovery_axis_multiplies_inside_the_channel_axis() {
        let mut grid = tiny_grid();
        grid.channels = vec![ChannelModel::Perfect, ChannelModel::Bernoulli { p: 0.2 }];
        grid.recoveries = vec![Recovery::Arq, Recovery::Fec];
        // 2 sigmas × 2 aggregators × 2 channels × 2 recoveries.
        let cells = grid.cells();
        assert_eq!(cells.len(), 16);
        // Recovery is inner relative to channel, outer relative to seed.
        assert_eq!(cells[0].recovery, Recovery::Arq);
        assert_eq!(cells[1].recovery, Recovery::Fec);
        assert_eq!(cells[0].channel, ChannelModel::Perfect);
        assert_eq!(cells[2].channel, ChannelModel::Bernoulli { p: 0.2 });
    }

    #[test]
    fn arq_cells_serialize_the_pre_fec_schema_byte_identically() {
        // A grid that never sets the recovery axis and one that pins it
        // to the ARQ default must render the same bytes — JSON and CSV.
        let mut base = tiny_grid().base;
        base.rounds = 6;
        let mut implicit = SweepGrid::new("golden", base.clone());
        implicit.channels = vec![ChannelModel::Bernoulli { p: 0.3 }];
        let mut explicit = implicit.clone();
        explicit.recoveries = vec![Recovery::Arq];
        let a = implicit.run(1);
        let b = explicit.run(1);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.csv().to_string(), b.csv().to_string());
        // And the pre-FEC schema carries no recovery vocabulary at all.
        let json = a.to_json().to_string();
        assert!(!json.contains("\"recovery\""));
        assert!(!json.contains("fec_recoveries"));
        assert!(!json.contains("equivocations"));
        assert!(!a.csv().to_string().contains("recovery"));
    }

    #[test]
    fn non_arq_cells_carry_the_recovery_fields_and_label_suffix() {
        let mut base = tiny_grid().base;
        base.rounds = 6;
        let mut grid = SweepGrid::new("fec", base);
        grid.channels = vec![ChannelModel::Bernoulli { p: 0.3 }];
        grid.recoveries = vec![Recovery::Arq, Recovery::Fec, Recovery::Hybrid];
        let report = grid.run(1);
        assert_eq!(report.cells.len(), 3);
        let json = report.to_json().to_string();
        assert!(json.contains("\"recovery\":\"fec\""));
        assert!(json.contains("\"recovery\":\"hybrid\""));
        assert!(json.contains("\"fec_recoveries\""));
        assert!(json.contains("\"equivocations\""));
        // Exactly the two non-ARQ cells carry the field.
        assert_eq!(json.matches("\"recovery\":").count(), 2);
        assert!(report.cells[0].label.ends_with("_bern0.3"), "{}", report.cells[0].label);
        assert!(report.cells[1].label.ends_with("_bern0.3_fec"), "{}", report.cells[1].label);
        assert!(
            report.cells[2].label.ends_with("_bern0.3_hybrid"),
            "{}",
            report.cells[2].label
        );
        // FEC repaired at least one erasure somewhere at p = 0.3, and no
        // retransmission was ever charged to the pure-FEC cell.
        let fec = &report.cells[1];
        assert!(fec.error.is_none(), "{:?}", fec.error);
        assert_eq!(fec.channel_totals.retransmits, 0, "pure FEC never retransmits");
        assert!(fec.channel_totals.fec_recoveries > 0, "p=0.3 must exercise a repair");
        // The CSV gains the discipline columns for this report.
        let csv = report.csv().to_string();
        assert!(csv.contains(",recovery,fec_recoveries,equivocations,"));
        assert!(csv.contains(",fec,"));
    }

    #[test]
    fn f64_cells_serialize_the_pre_codec_schema_byte_identically() {
        // A grid that never sets the codec axis and one that pins it to
        // the f64 identity default must render the same bytes — JSON and
        // CSV — including across the lossy/recovery conditional fields.
        let mut base = tiny_grid().base;
        base.rounds = 6;
        let mut implicit = SweepGrid::new("golden-codec", base.clone());
        implicit.channels = vec![ChannelModel::Bernoulli { p: 0.3 }];
        implicit.recoveries = vec![Recovery::Arq, Recovery::Fec];
        let mut explicit = implicit.clone();
        explicit.codecs = vec![WireCodec::F64];
        let a = implicit.run(1);
        let b = explicit.run(1);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.csv().to_string(), b.csv().to_string());
        // And the pre-codec schema carries no codec vocabulary at all.
        let json = a.to_json().to_string();
        assert!(!json.contains("\"codec\""));
        assert!(!a.csv().to_string().contains("codec"));
    }

    #[test]
    fn codec_cells_carry_the_field_and_label_suffix() {
        let mut base = tiny_grid().base;
        base.rounds = 6;
        let mut grid = SweepGrid::new("codec-cells", base);
        grid.codecs = vec![WireCodec::F64, WireCodec::Int8, WireCodec::TopK(4)];
        let report = grid.run(1);
        assert_eq!(report.cells.len(), 3);
        let json = report.to_json().to_string();
        // Exactly the two non-f64 cells carry the field.
        assert_eq!(json.matches("\"codec\":").count(), 2);
        assert!(json.contains("\"codec\":\"int8\""));
        assert!(json.contains("\"codec\":\"topk4\""));
        assert!(!report.cells[0].label.contains("int8"));
        assert!(report.cells[1].label.ends_with("_int8"), "{}", report.cells[1].label);
        assert!(report.cells[2].label.ends_with("_topk4"), "{}", report.cells[2].label);
        // Compressed cells move fewer bits than the identity cell while
        // still converging (error recorded, no build failure).
        let f64_bits = report.cells[0].uplink_bits_total;
        let int8 = &report.cells[1];
        assert!(int8.error.is_none(), "{:?}", int8.error);
        assert!(int8.uplink_bits_total < f64_bits, "int8 must shrink the uplink");
        assert!(int8.final_loss.is_finite());
        // The CSV gains the codec column for this report, spliced before
        // empirical_rho.
        let csv = report.csv().to_string();
        assert!(csv.contains(",codec,empirical_rho,"));
        assert!(csv.contains(",int8,"));
    }

    #[test]
    fn codec_axis_nests_inside_recovery() {
        let mut grid = tiny_grid();
        grid.recoveries = vec![Recovery::Arq, Recovery::Fec];
        grid.codecs = vec![WireCodec::F64, WireCodec::Sign];
        grid.seeds = vec![1, 2];
        // 2 sigmas × 2 aggregators × 2 recoveries × 2 codecs × 2 seeds.
        let cells = grid.cells();
        assert_eq!(cells.len(), 32);
        assert_eq!(cells[0].codec, WireCodec::F64);
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[1].seed, 2);
        assert_eq!(cells[2].codec, WireCodec::Sign);
        assert_eq!(cells[4].recovery, Recovery::Fec);
    }

    #[test]
    fn channel_axis_multiplies_into_the_cross_product() {
        let mut grid = tiny_grid();
        grid.channels = vec![ChannelModel::Perfect, ChannelModel::Bernoulli { p: 0.2 }];
        // 2 sigmas × 2 aggregators × 2 channels.
        let cells = grid.cells();
        assert_eq!(cells.len(), 8);
        // Channel is inner relative to aggregator, outer relative to seed.
        assert_eq!(cells[0].channel, ChannelModel::Perfect);
        assert_eq!(cells[1].channel, ChannelModel::Bernoulli { p: 0.2 });
        assert_eq!(cells[2].channel, ChannelModel::Perfect);
    }

    #[test]
    fn lossy_cells_serialize_channel_and_casualties() {
        let mut base = tiny_grid().base;
        base.rounds = 6;
        let mut grid = SweepGrid::new("lossy", base);
        grid.channels = vec![ChannelModel::Perfect, ChannelModel::Bernoulli { p: 0.4 }];
        let report = grid.run(1);
        assert_eq!(report.cells.len(), 2);
        let json = report.to_json().to_string();
        // Exactly the lossy cell carries the channel fields.
        assert_eq!(json.matches("\"channel\":").count(), 1);
        assert!(json.contains("\"channel\":\"bernoulli=0.4\""));
        assert!(json.contains("\"dropped_frames\""));
        let lossy = &report.cells[1];
        assert!(lossy.channel_totals.dropped_frames > 0, "p=0.4 must drop something");
        assert!(lossy.label.ends_with("_bern0.4"), "label = {}", lossy.label);
        let perfect = &report.cells[0];
        assert_eq!(perfect.channel_totals.dropped_frames, 0);
        assert!(!perfect.label.contains("bern"));
        // The CSV always carries the channel column.
        let csv = report.csv().to_string();
        assert!(csv.contains(",channel,"));
        assert!(csv.contains(",bern0.4,"));
    }

    #[test]
    fn churn_free_cells_serialize_the_pre_churn_schema_byte_identically() {
        // A grid that never sets the membership axes and one that pins
        // them to their defaults (churn 0, straggler 0, IID) must render
        // the same bytes — JSON and CSV — including across the lossy
        // conditional fields.
        let mut base = tiny_grid().base;
        base.rounds = 6;
        let mut implicit = SweepGrid::new("golden-churn", base.clone());
        implicit.channels = vec![ChannelModel::Bernoulli { p: 0.3 }];
        let mut explicit = implicit.clone();
        explicit.churns = vec![0.0];
        explicit.stragglers = vec![0.0];
        explicit.alphas = vec![None];
        let a = implicit.run(1);
        let b = explicit.run(1);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.csv().to_string(), b.csv().to_string());
        // And the pre-churn schema carries no membership vocabulary.
        let json = a.to_json().to_string();
        assert!(!json.contains("\"churn\""));
        assert!(!json.contains("\"straggler\""));
        assert!(!json.contains("\"alpha\""));
        assert!(!json.contains("\"absent\""));
        assert!(!json.contains("\"late\""));
        let csv = a.csv().to_string();
        assert!(!csv.contains("churn"));
        assert!(!csv.contains("straggler"));
        assert!(!csv.contains("alpha"));
    }

    #[test]
    fn churned_cells_carry_the_fields_and_label_suffixes() {
        let mut base = tiny_grid().base;
        base.rounds = 6;
        // Summary retention: the counts below pin the *cell-level*
        // fields, not the per-round trace columns.
        base.trace = TracePolicy::Summary;
        let mut grid = SweepGrid::new("churny", base);
        grid.churns = vec![0.0, 0.3];
        grid.stragglers = vec![0.0, 0.5];
        let report = grid.run(1);
        assert_eq!(report.cells.len(), 4);
        for c in &report.cells {
            assert!(c.error.is_none(), "{:?}", c.error);
            assert!(c.final_loss.is_finite());
        }
        let json = report.to_json().to_string();
        // Exactly the two churned cells / two straggler cells carry the
        // fields and counters.
        assert_eq!(json.matches("\"churn\":").count(), 2);
        assert_eq!(json.matches("\"straggler\":").count(), 2);
        assert_eq!(json.matches("\"absent\":").count(), 2);
        assert_eq!(json.matches("\"late\":").count(), 2);
        assert!(!report.cells[0].label.contains("churn"));
        assert!(report.cells[1].label.ends_with("_strag0.5"), "{}", report.cells[1].label);
        assert!(report.cells[2].label.ends_with("_churn0.3"), "{}", report.cells[2].label);
        assert!(
            report.cells[3].label.ends_with("_churn0.3_strag0.5"),
            "{}",
            report.cells[3].label
        );
        // Churn at p = 0.3 over 6 rounds of 10 workers removes someone;
        // straggling at p = 0.5 misses a deadline somewhere.
        assert!(report.cells[2].absent > 0, "churn must remove a worker");
        assert!(report.cells[1].late > 0, "stragglers must miss a deadline");
        assert_eq!(report.cells[0].absent, 0);
        assert_eq!(report.cells[0].late, 0);
        // The CSV gains the membership columns, spliced before
        // empirical_rho.
        let csv = report.csv().to_string();
        assert!(csv.contains(",churn,absent,straggler,late,empirical_rho,"));
    }

    #[test]
    fn membership_axes_nest_between_codec_and_seed() {
        let mut grid = tiny_grid();
        grid.sigmas = vec![0.05];
        grid.aggregators = vec![Aggregator::CgcSum];
        grid.codecs = vec![WireCodec::F64, WireCodec::Sign];
        grid.churns = vec![0.0, 0.2];
        grid.stragglers = vec![0.0, 0.1];
        grid.alphas = vec![None];
        grid.seeds = vec![1, 2];
        // 2 codecs × 2 churns × 2 stragglers × 1 alpha × 2 seeds.
        let cells = grid.cells();
        assert_eq!(cells.len(), 16);
        assert_eq!(cells[0].seed, 1);
        assert_eq!(cells[1].seed, 2);
        assert_eq!(cells[2].straggler, 0.1);
        assert_eq!(cells[4].churn, 0.2);
        assert_eq!(cells[8].codec, WireCodec::Sign);
        assert!(cells.iter().all(|c| c.alpha.is_none()));
    }

    #[test]
    fn empirical_rho_windows_the_contracting_prefix() {
        // Synthetic geometric decay: rho recovered exactly.
        let recs: Vec<RoundEvent> = (0..20)
            .map(|t| RoundEvent {
                round: t,
                loss: 0.0,
                dist_sq: Some(4.0 * 0.5f64.powi(t as i32)),
                grad_norm: 0.0,
                uplink_bits: 0,
                echo_count: 0,
                raw_count: 0,
                exposed_cum: 0,
                clipped: 0,
                dropped_frames: 0,
                retransmits: 0,
                fallbacks: 0,
                absent: 0,
                late: 0,
            })
            .collect();
        let rho = empirical_rho(&recs).unwrap();
        assert!((rho - 0.5).abs() < 0.03, "rho {rho}");
        assert_eq!(empirical_rho(&[]), None);
    }

    #[test]
    fn traced_cells_serialize_their_trajectory() {
        let mut base = tiny_grid().base;
        base.trace = TracePolicy::EveryK { every_k: 2, max_points: 16 };
        let grid = SweepGrid::new("traced", base);
        let report = grid.run(1);
        let cell = &report.cells[0];
        assert_eq!(cell.trace_policy, TracePolicy::EveryK { every_k: 2, max_points: 16 });
        // Rounds 0,2,4,6 on the grid plus the final round 7 as the tail.
        let rounds: Vec<usize> = cell.trace.iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![0, 2, 4, 6, 7]);
        let json = report.to_json().to_string();
        assert!(json.contains("\"trace_policy\":\"every_k=2,max=16\""));
        assert!(json.contains("\"dist_sq\""));
        // Summary-policy cells serialize a null trace.
        let mut base = tiny_grid().base;
        base.trace = TracePolicy::Summary;
        let report = SweepGrid::new("scalar", base).run(1);
        assert!(report.cells[0].trace.is_empty());
        let json = report.to_json().to_string();
        assert!(json.contains("\"trace\":null"));
    }
}
