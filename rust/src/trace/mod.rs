//! The round-trace observer pipeline: typed per-round events, pluggable
//! sinks, and the online contraction fit.
//!
//! The round engine ([`crate::sim::Simulation`]) emits one [`RoundEvent`]
//! per synchronous round — loss, `‖w − w*‖²`, echo/raw frame counts, bits
//! on air, CGC filter decisions — to a [`RoundObserver`]. Three sinks
//! cover the retention policies an experiment needs:
//!
//! * [`FullTrace`] retains every event (the default — what `train` CSVs
//!   and the engine's own tests read back);
//! * [`BoundedTrace`] retains an every-k decimation under a hard point
//!   cap: when the cap is hit, `k` doubles and the retained window is
//!   re-decimated in place, so an arbitrarily long horizon keeps at most
//!   `max_points` events (plus the final round, which always rides along
//!   in [`TraceSink::points`]). This is the sweep engine's trajectory
//!   capture;
//! * [`SummaryOnly`] retains no per-round events at all.
//!
//! Every sink also folds a [`TraceSummary`] online — first/final loss and
//! distance plus the [`RhoFit`] contraction estimate — so scalar outcomes
//! (`final_loss`, `empirical_rho`) are identical under every retention
//! policy: the summary observes each event exactly once, whether or not
//! the event is retained.
//!
//! Which sink a simulation gets is chosen by [`TracePolicy`]
//! (`ExperimentConfig::trace`; CLI `--trace summary|full|every_k=K,max=M`).
//! Retention is a pure function of the policy and the round indices —
//! never of wall clock or thread schedule — so traced sweep reports
//! inherit the engine's determinism contract: byte-identical JSON at any
//! thread count (pinned by `rust/tests/trace.rs`).

/// Per-round measurements, emitted once per synchronous round.
#[derive(Clone, Copy, Debug)]
pub struct RoundEvent {
    pub round: usize,
    /// `Q(w^t)` (full-dataset loss at the *start* of the round).
    pub loss: f64,
    /// `‖w^t − w*‖²` when the optimum is known.
    pub dist_sq: Option<f64>,
    /// `‖∇Q(w^t)‖`.
    pub grad_norm: f64,
    /// Worker→server bits this round.
    pub uplink_bits: u64,
    /// Echo / raw frame counts among *fault-free* workers, classified by
    /// what ultimately served the slot: an echo that fell back to raw on
    /// a lossy uplink counts as raw (the attempt is in `fallbacks`).
    pub echo_count: usize,
    pub raw_count: usize,
    /// Byzantine workers exposed so far (cumulative).
    pub exposed_cum: usize,
    /// Gradients clipped by the CGC filter this round (0 under non-CGC
    /// aggregation rules) — the server's per-round filter decisions.
    pub clipped: usize,
    /// Channel casualties this round: (listener, frame) pairs an honest
    /// listener missed on the lossy radio. 0 under the perfect channel.
    pub dropped_frames: usize,
    /// Uplink retransmissions this round (server-bound ARQ attempts
    /// beyond the first). 0 under the perfect channel.
    pub retransmits: usize,
    /// Echo→raw fallbacks this round (the server missed, or could not
    /// reconstruct, an honest echo). 0 under the perfect channel.
    pub fallbacks: usize,
    /// Workers absent from this round's churn roster (their slots were
    /// removed from the TDMA schedule and the server zeroed them without
    /// exposure). 0 without churn.
    pub absent: usize,
    /// Honest workers whose gradient missed the round deadline (slot kept
    /// but elapsed without a frame; scored `Lost`, never exposed — slow is
    /// not Byzantine). 0 without stragglers.
    pub late: usize,
}

/// Anything that wants to see the round stream. Events arrive in round
/// order, exactly once each.
pub trait RoundObserver: Send {
    fn on_round(&mut self, ev: &RoundEvent);
}

/// Default point cap for `every_k=K` policies given without `max=M`.
pub const DEFAULT_MAX_POINTS: usize = 512;

/// Per-round retention policy (`ExperimentConfig::trace`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePolicy {
    /// Scalar summary only; no per-round retention.
    Summary,
    /// Every `every_k`-th round, at most `max_points` retained (the cap
    /// doubles `every_k` and re-decimates — see [`BoundedTrace`]).
    EveryK { every_k: usize, max_points: usize },
    /// Every round (the `ExperimentConfig` default).
    Full,
}

impl TracePolicy {
    /// Parse `summary|off|none`, `full|all`, or a comma list of
    /// `every_k=K` / `max=M` pairs (`every_k=4,max=128`; `max` defaults
    /// to [`DEFAULT_MAX_POINTS`]). Zero values are rejected.
    pub fn parse(s: &str) -> Option<TracePolicy> {
        match s {
            "summary" | "off" | "none" => return Some(TracePolicy::Summary),
            "full" | "all" => return Some(TracePolicy::Full),
            _ => {}
        }
        let mut every_k = 1usize;
        let mut max_points = DEFAULT_MAX_POINTS;
        let mut any = false;
        for part in s.split(',') {
            let (k, v) = part.split_once('=')?;
            let v: usize = v.trim().parse().ok()?;
            match k.trim() {
                "every_k" | "k" => every_k = v,
                "max" | "max_points" => max_points = v,
                _ => return None,
            }
            any = true;
        }
        if !any || every_k == 0 || max_points == 0 {
            return None;
        }
        Some(TracePolicy::EveryK { every_k, max_points })
    }

    /// Canonical textual form (round-trips through [`Self::parse`]).
    pub fn label(&self) -> String {
        match self {
            TracePolicy::Summary => "summary".to_string(),
            TracePolicy::Full => "full".to_string(),
            TracePolicy::EveryK { every_k, max_points } => {
                format!("every_k={every_k},max={max_points}")
            }
        }
    }
}

/// Online fit of the per-round contraction `ρ` of `‖wᵗ − w*‖²` over the
/// contracting prefix: the geometric mean of the per-round ratio between
/// the first finite positive distance and the last one above the
/// quantization floor (the f32 wire floor stalls the distance at ~1e-14,
/// so rounds past it are excluded — the same windowing the convergence
/// bench has always used).
///
/// Degenerate trajectories yield `None` instead of a garbage estimate:
/// no finite positive distance at all (all-`None`/NaN), a single observed
/// round, or a start already at the floor (flat-at-floor).
#[derive(Clone, Copy, Debug, Default)]
pub struct RhoFit {
    start: Option<(usize, f64)>,
    last: Option<(usize, f64)>,
    floor: f64,
    stalled: bool,
}

impl RhoFit {
    /// Feed one round's `‖w − w*‖²`. Missing and non-finite values are
    /// skipped; the first value below the floor freezes the window.
    pub fn observe(&mut self, round: usize, dist_sq: Option<f64>) {
        if self.stalled {
            return;
        }
        let v = match dist_sq {
            Some(v) if v.is_finite() => v,
            _ => return,
        };
        match self.start {
            None => {
                if v > 0.0 {
                    self.start = Some((round, v));
                    self.last = self.start;
                    self.floor = 1e-10 * v.max(1.0);
                }
            }
            Some(_) => {
                if v < self.floor {
                    self.stalled = true;
                } else {
                    self.last = Some((round, v));
                }
            }
        }
    }

    /// The fitted per-round contraction, or `None` for a degenerate
    /// trajectory (see the type docs).
    pub fn rho(&self) -> Option<f64> {
        let (r0, d0) = self.start?;
        let (r1, dt) = self.last?;
        if r1 <= r0 || dt <= 0.0 {
            return None;
        }
        let rho = (dt / d0).powf(1.0 / (r1 - r0) as f64);
        if rho.is_finite() {
            Some(rho)
        } else {
            None
        }
    }

    /// The fit window `(first round, anchor d0, last round above the
    /// floor)` — what the curves renderer overlays the fit on.
    pub fn window(&self) -> Option<(usize, f64, usize)> {
        let (r0, d0) = self.start?;
        let (r1, _) = self.last?;
        if r1 <= r0 {
            None
        } else {
            Some((r0, d0, r1))
        }
    }
}

/// Geometric-mean per-round contraction of a recorded trajectory —
/// [`RhoFit`] folded over the events. `None` for degenerate trajectories.
pub fn empirical_rho(events: &[RoundEvent]) -> Option<f64> {
    let mut fit = RhoFit::default();
    for ev in events {
        fit.observe(ev.round, ev.dist_sq);
    }
    fit.rho()
}

/// Scalar outcomes folded online from the round stream — identical under
/// every retention policy (every sink feeds it every event).
#[derive(Clone, Copy, Debug)]
pub struct TraceSummary {
    /// Rounds observed so far.
    pub rounds: usize,
    pub first_loss: f64,
    pub final_loss: f64,
    pub first_dist_sq: Option<f64>,
    /// Last *defined* `‖w − w*‖²` seen (measured at round start).
    pub final_dist_sq: Option<f64>,
    /// The online contraction fit.
    pub fit: RhoFit,
}

impl Default for TraceSummary {
    fn default() -> Self {
        TraceSummary {
            rounds: 0,
            first_loss: f64::NAN,
            final_loss: f64::NAN,
            first_dist_sq: None,
            final_dist_sq: None,
            fit: RhoFit::default(),
        }
    }
}

impl TraceSummary {
    pub fn observe(&mut self, ev: &RoundEvent) {
        if self.rounds == 0 {
            self.first_loss = ev.loss;
            self.first_dist_sq = ev.dist_sq;
        }
        self.rounds += 1;
        self.final_loss = ev.loss;
        if ev.dist_sq.is_some() {
            self.final_dist_sq = ev.dist_sq;
        }
        self.fit.observe(ev.round, ev.dist_sq);
    }
}

/// Sink retaining every event.
#[derive(Clone, Debug, Default)]
pub struct FullTrace {
    pub summary: TraceSummary,
    pub events: Vec<RoundEvent>,
}

impl RoundObserver for FullTrace {
    fn on_round(&mut self, ev: &RoundEvent) {
        self.summary.observe(ev);
        self.events.push(*ev);
    }
}

/// Sink retaining only the online summary.
#[derive(Clone, Copy, Debug, Default)]
pub struct SummaryOnly {
    pub summary: TraceSummary,
}

impl RoundObserver for SummaryOnly {
    fn on_round(&mut self, ev: &RoundEvent) {
        self.summary.observe(ev);
    }
}

/// Sink retaining an every-k decimation under a hard cap. Retention is a
/// pure function of `(every_k, max_points)` and the round indices, so two
/// runs of the same config retain byte-identical windows regardless of
/// thread count.
#[derive(Clone, Debug)]
pub struct BoundedTrace {
    pub summary: TraceSummary,
    every_k: usize,
    max_points: usize,
    kept: Vec<RoundEvent>,
    tail: Option<RoundEvent>,
}

impl BoundedTrace {
    pub fn new(every_k: usize, max_points: usize) -> BoundedTrace {
        BoundedTrace {
            summary: TraceSummary::default(),
            every_k: every_k.max(1),
            max_points: max_points.max(1),
            kept: Vec::new(),
            tail: None,
        }
    }

    /// The decimation stride currently in effect (doubles at the cap).
    pub fn effective_every_k(&self) -> usize {
        self.every_k
    }
}

impl RoundObserver for BoundedTrace {
    fn on_round(&mut self, ev: &RoundEvent) {
        self.summary.observe(ev);
        self.tail = Some(*ev);
        // Cap: coarsen the grid (double k) and re-decimate in place until
        // either the event no longer lands on the grid or space frees up.
        while ev.round % self.every_k == 0 && self.kept.len() >= self.max_points {
            self.every_k *= 2;
            let k = self.every_k;
            self.kept.retain(|e| e.round % k == 0);
        }
        if ev.round % self.every_k == 0 && self.kept.len() < self.max_points {
            self.kept.push(*ev);
        }
    }
}

/// A policy-selected sink, owned by the simulation.
#[derive(Clone, Debug)]
pub enum TraceSink {
    Summary(SummaryOnly),
    Bounded(BoundedTrace),
    Full(FullTrace),
}

impl TraceSink {
    pub fn new(policy: TracePolicy) -> TraceSink {
        match policy {
            TracePolicy::Summary => TraceSink::Summary(SummaryOnly::default()),
            TracePolicy::EveryK { every_k, max_points } => {
                TraceSink::Bounded(BoundedTrace::new(every_k, max_points))
            }
            TracePolicy::Full => TraceSink::Full(FullTrace::default()),
        }
    }

    /// The online scalar summary (defined under every policy).
    pub fn summary(&self) -> &TraceSummary {
        match self {
            TraceSink::Summary(t) => &t.summary,
            TraceSink::Bounded(t) => &t.summary,
            TraceSink::Full(t) => &t.summary,
        }
    }

    /// The retained event window (empty under `Summary`; decimated under
    /// `Bounded` — use [`Self::points`] to include the final round).
    pub fn retained(&self) -> &[RoundEvent] {
        match self {
            TraceSink::Summary(_) => &[],
            TraceSink::Bounded(t) => &t.kept,
            TraceSink::Full(t) => &t.events,
        }
    }

    /// The retained window as an owned trajectory, with the most recent
    /// round appended when decimation dropped it — what sweep cells
    /// serialize and the curves renderer plots.
    pub fn points(&self) -> Vec<RoundEvent> {
        match self {
            TraceSink::Summary(_) => Vec::new(),
            TraceSink::Full(t) => t.events.clone(),
            TraceSink::Bounded(t) => {
                let mut out = t.kept.clone();
                if let Some(tail) = t.tail {
                    match out.last() {
                        Some(e) if e.round == tail.round => {}
                        _ => out.push(tail),
                    }
                }
                out
            }
        }
    }
}

impl RoundObserver for TraceSink {
    fn on_round(&mut self, ev: &RoundEvent) {
        match self {
            TraceSink::Summary(t) => t.on_round(ev),
            TraceSink::Bounded(t) => t.on_round(ev),
            TraceSink::Full(t) => t.on_round(ev),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(round: usize, dist: Option<f64>) -> RoundEvent {
        RoundEvent {
            round,
            loss: round as f64,
            dist_sq: dist,
            grad_norm: 0.0,
            uplink_bits: 1,
            echo_count: 0,
            raw_count: 0,
            exposed_cum: 0,
            clipped: 0,
            dropped_frames: 0,
            retransmits: 0,
            fallbacks: 0,
            absent: 0,
            late: 0,
        }
    }

    #[test]
    fn policy_parse_roundtrips_and_rejects_garbage() {
        for p in [
            TracePolicy::Summary,
            TracePolicy::Full,
            TracePolicy::EveryK { every_k: 4, max_points: 128 },
        ] {
            assert_eq!(TracePolicy::parse(&p.label()), Some(p));
        }
        assert_eq!(
            TracePolicy::parse("every_k=8"),
            Some(TracePolicy::EveryK { every_k: 8, max_points: DEFAULT_MAX_POINTS })
        );
        assert_eq!(TracePolicy::parse("off"), Some(TracePolicy::Summary));
        assert_eq!(TracePolicy::parse("bogus"), None);
        assert_eq!(TracePolicy::parse("every_k=0"), None);
        assert_eq!(TracePolicy::parse("max=0"), None);
        assert_eq!(TracePolicy::parse("every_k=x"), None);
        assert_eq!(TracePolicy::parse(""), None);
    }

    #[test]
    fn bounded_trace_decimates_on_the_k_grid() {
        let mut sink = TraceSink::new(TracePolicy::EveryK { every_k: 5, max_points: 100 });
        for t in 0..23 {
            sink.on_round(&ev(t, Some(1.0)));
        }
        let rounds: Vec<usize> = sink.retained().iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![0, 5, 10, 15, 20]);
        // `points()` appends the final round the decimation dropped.
        let pts: Vec<usize> = sink.points().iter().map(|e| e.round).collect();
        assert_eq!(pts, vec![0, 5, 10, 15, 20, 22]);
    }

    #[test]
    fn bounded_trace_cap_coarsens_the_grid() {
        let mut sink = BoundedTrace::new(1, 8);
        for t in 0..200 {
            sink.on_round(&ev(t, Some(1.0)));
        }
        assert!(sink.kept.len() <= 8, "cap violated: {}", sink.kept.len());
        let k = sink.effective_every_k();
        assert!(k > 1 && k.is_power_of_two());
        assert!(sink.kept.iter().all(|e| e.round % k == 0));
        assert!(sink.kept.windows(2).all(|w| w[0].round < w[1].round));
        // The summary saw every round even though few were retained.
        assert_eq!(sink.summary.rounds, 200);
    }

    #[test]
    fn summary_is_identical_under_every_policy() {
        let events: Vec<RoundEvent> =
            (0..50).map(|t| ev(t, Some(4.0 * 0.8f64.powi(t as i32)))).collect();
        let mut sinks = [
            TraceSink::new(TracePolicy::Summary),
            TraceSink::new(TracePolicy::EveryK { every_k: 3, max_points: 7 }),
            TraceSink::new(TracePolicy::Full),
        ];
        for sink in sinks.iter_mut() {
            for e in &events {
                sink.on_round(e);
            }
        }
        let rho0 = sinks[0].summary().fit.rho().unwrap();
        for sink in &sinks {
            let s = sink.summary();
            assert_eq!(s.rounds, 50);
            assert_eq!(s.final_loss.to_bits(), 49.0f64.to_bits());
            assert_eq!(s.fit.rho().unwrap().to_bits(), rho0.to_bits());
        }
        assert!((rho0 - 0.8).abs() < 1e-12);
        assert!(sinks[0].retained().is_empty());
        assert_eq!(sinks[2].retained().len(), 50);
    }

    #[test]
    fn rho_fit_recovers_exact_geometric_decay() {
        let events: Vec<RoundEvent> =
            (0..20).map(|t| ev(t, Some(4.0 * 0.5f64.powi(t as i32)))).collect();
        let rho = empirical_rho(&events).unwrap();
        assert!((rho - 0.5).abs() < 1e-12, "rho {rho}");
    }

    #[test]
    fn rho_fit_windows_out_the_quantization_floor() {
        // Decay to ~1e-14, then flat: the stalled suffix must not drag
        // the estimate down.
        let events: Vec<RoundEvent> =
            (0..200).map(|t| ev(t, Some((4.0 * 0.5f64.powi(t as i32)).max(1e-14)))).collect();
        let rho = empirical_rho(&events).unwrap();
        assert!((rho - 0.5).abs() < 0.03, "rho {rho}");
    }

    #[test]
    fn rho_fit_is_none_for_degenerate_trajectories() {
        assert_eq!(empirical_rho(&[]), None);
        // Single round: no window.
        assert_eq!(empirical_rho(&[ev(0, Some(4.0))]), None);
        // All-missing and all-NaN distances.
        let none: Vec<RoundEvent> = (0..5).map(|t| ev(t, None)).collect();
        assert_eq!(empirical_rho(&none), None);
        let nan: Vec<RoundEvent> = (0..5).map(|t| ev(t, Some(f64::NAN))).collect();
        assert_eq!(empirical_rho(&nan), None);
        // Flat at the floor: the start is already below its own floor.
        let flat: Vec<RoundEvent> = (0..5).map(|t| ev(t, Some(1e-20))).collect();
        assert_eq!(empirical_rho(&flat), None);
        // Nonpositive start never anchors a window.
        let zeros: Vec<RoundEvent> = (0..5).map(|t| ev(t, Some(0.0))).collect();
        assert_eq!(empirical_rho(&zeros), None);
    }

    #[test]
    fn rho_fit_skips_gaps_and_uses_round_distance() {
        // Decimated observations (rounds 0, 10, 20) of a 0.9-per-round
        // decay still recover 0.9: the exponent uses round distance.
        let mut fit = RhoFit::default();
        for &r in &[0usize, 10, 20] {
            fit.observe(r, Some(100.0 * 0.9f64.powi(r as i32)));
        }
        let rho = fit.rho().unwrap();
        assert!((rho - 0.9).abs() < 1e-12, "rho {rho}");
        let (r0, d0, r1) = fit.window().unwrap();
        assert_eq!((r0, r1), (0, 20));
        assert_eq!(d0.to_bits(), 100.0f64.to_bits());
    }
}
