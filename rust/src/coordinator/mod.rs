//! The parameter server (Algorithm 1, server side) and the aggregation
//! rules — the paper's CGC filter plus the standard Byzantine-tolerant
//! baselines it is compared against.
//!
//! The server side of one round:
//!
//! 1. **Overhear bookkeeping** — the server (like every worker) records
//!    each slot's broadcast; raw gradients fill the reference set `G`,
//!    echo messages are kept symbolic until reconstruction.
//! 2. **Echo reconstruction** — an echo `(S, x, ‖g‖)` names earlier
//!    slots `S` and coefficients `x`; the server rebuilds the intended
//!    gradient from its own overheard history. A reference to a slot
//!    that never transmitted *proves* the sender Byzantine (reliable
//!    local broadcast), and [`ParameterServer::exposed`] accumulates
//!    such proofs across rounds.
//! 3. **Aggregation** — [`cgc_scales`] implements Eq. (8)'s clip rule
//!    (the `(n−f)`-th norm as threshold); [`cgc_sum_fused`] and the
//!    parallel fused path in [`server`] derive from it, so tie-breaking
//!    lives in exactly one place. [`Aggregator`] selects CGC or a
//!    baseline ([`aggregate`]): mean, Krum, coordinate-wise median,
//!    trimmed mean — all on the same substrate, all generic over
//!    `AsRef<[f64]>` so borrowed gradient slices aggregate without the
//!    per-round O(n·d) clone.
//!
//! The norm pass and the fused CGC sum fan out across the scoped thread
//! pool ([`crate::par`]) with serial accumulation order preserved —
//! bitwise-equal results at any thread count (pinned by
//! `rust/tests/determinism.rs`).

pub mod aggregators;
pub mod server;

pub use aggregators::{
    aggregate, cgc_filter, cgc_filter_report, cgc_scales, cgc_sum_fused, Aggregator,
};
pub use server::{ParameterServer, SlotOutcome};
