//! The parameter server (Algorithm 1, server side) and the aggregation
//! rules — the paper's CGC filter plus the standard Byzantine-tolerant
//! baselines it is compared against.

pub mod aggregators;
pub mod server;

pub use aggregators::{
    aggregate, cgc_filter, cgc_filter_report, cgc_scales, cgc_sum_fused, Aggregator,
};
pub use server::{ParameterServer, SlotOutcome};
