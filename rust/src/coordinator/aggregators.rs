//! Aggregation rules: the paper's CGC filter (Eq. 8) plus the standard
//! Byzantine-tolerant baselines it is evaluated against.
//!
//! **Scaling convention.** The paper's update is `w ← w − η Σ_j ĝ_j`
//! (Eq. 2: a *sum*, not a mean). To let one step size work for every rule,
//! every aggregator returns a sum-equivalent vector: `Mean` returns
//! `Σ g_j` (= n·mean), `Krum` returns `n·(Krum winner)`, and so on. The
//! comparison benches therefore sweep the same η for every rule.

use crate::linalg::{self, norm};

/// Selectable aggregation rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregator {
    /// The CGC filter of Gupta & Vaidya (PODC 2020), Eq. (8): clip the f
    /// largest norms to the (n−f)-th norm, then sum. Echo-CGC = echo
    /// mechanism + this rule.
    CgcSum,
    /// Fault-intolerant baseline: plain sum (gradient descent).
    Mean,
    /// Krum (Blanchard et al., NeurIPS 2017): the gradient with minimal sum
    /// of squared distances to its n−f−2 nearest neighbours, scaled by n.
    Krum,
    /// Coordinate-wise median × n.
    CoordMedian,
    /// Coordinate-wise trimmed mean (drop f smallest and f largest per
    /// coordinate) × n.
    TrimmedMean,
}

impl Aggregator {
    pub fn name(self) -> &'static str {
        match self {
            Aggregator::CgcSum => "cgc",
            Aggregator::Mean => "mean",
            Aggregator::Krum => "krum",
            Aggregator::CoordMedian => "median",
            Aggregator::TrimmedMean => "trimmed-mean",
        }
    }

    pub fn parse(s: &str) -> Option<Aggregator> {
        Some(match s {
            "cgc" | "echo-cgc" => Aggregator::CgcSum,
            "mean" | "sum" => Aggregator::Mean,
            "krum" => Aggregator::Krum,
            "median" | "coord-median" => Aggregator::CoordMedian,
            "trimmed-mean" | "trimmed" => Aggregator::TrimmedMean,
            _ => return None,
        })
    }

    pub fn all() -> [Aggregator; 5] {
        [
            Aggregator::CgcSum,
            Aggregator::Mean,
            Aggregator::Krum,
            Aggregator::CoordMedian,
            Aggregator::TrimmedMean,
        ]
    }
}

/// CGC clip scales from the norm vector (Eq. 8's per-gradient factors):
/// `1.0` at or below the `(n−f)`-th smallest norm, `threshold/‖g_j‖`
/// above it (`0.0` for a pathological zero-norm "large" gradient).
/// Returns `(scales, clipped ids ascending)`.
///
/// This is **the** clip rule — [`cgc_filter_report`], [`cgc_sum_fused`]
/// and the server's parallel fused path all derive from it, so
/// tie-breaking and threshold selection live in exactly one place.
pub fn cgc_scales(norms: &[f64], f: usize) -> (Vec<f64>, Vec<usize>) {
    let n = norms.len();
    assert!(f < n, "need f < n");
    let mut scales = vec![1.0; n];
    let mut clipped = Vec::new();
    if f == 0 {
        return (scales, clipped);
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| norms[a].partial_cmp(&norms[b]).unwrap().then(a.cmp(&b)));
    let threshold = norms[order[n - f - 1]];
    for (j, &nj) in norms.iter().enumerate() {
        if nj > threshold {
            clipped.push(j);
            scales[j] = if nj > 0.0 { threshold / nj } else { 0.0 };
        }
    }
    (scales, clipped)
}

/// CGC filter + report of which slots were clipped (feeds the server's
/// suspicion scores: honest workers are clipped only occasionally, a
/// norm-inflating Byzantine every round).
///
/// Generic over `AsRef<[f64]>` (owned vectors or borrowed slices), like
/// every rule in this module, so the server can aggregate its stored
/// gradients without cloning them first.
pub fn cgc_filter_report<G: AsRef<[f64]>>(grads: &[G], f: usize) -> (Vec<Vec<f64>>, Vec<usize>) {
    let mut out: Vec<Vec<f64>> = grads.iter().map(|g| g.as_ref().to_vec()).collect();
    if f == 0 {
        assert!(!grads.is_empty(), "need f < n");
        return (out, Vec::new());
    }
    let norms: Vec<f64> = grads.iter().map(|g| norm(g.as_ref())).collect();
    let (scales, clipped) = cgc_scales(&norms, f);
    for &j in &clipped {
        linalg::scale_mut(scales[j], &mut out[j]);
    }
    (out, clipped)
}

/// Apply the CGC filter (Eq. 8) and return the filtered gradients `ĝ_j`.
///
/// Sort the norms ascending; gradients ranked above `n−f` are scaled down
/// to the `(n−f)`-th norm; the rest pass unchanged. Zero vectors (exposed
/// Byzantine slots) sort first and pass unchanged, as in the paper.
pub fn cgc_filter<G: AsRef<[f64]>>(grads: &[G], f: usize) -> Vec<Vec<f64>> {
    cgc_filter_report(grads, f).0
}

fn krum_select<G: AsRef<[f64]>>(grads: &[G], f: usize) -> usize {
    let n = grads.len();
    // Krum needs n > 2f + 2; fall back to the full-neighbour score when the
    // margin is too small (still well-defined).
    let k = n.saturating_sub(f + 2).max(1);
    let mut dist2 = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in i + 1..n {
            let d2 = {
                let mut s = 0.0;
                for (a, b) in grads[i].as_ref().iter().zip(grads[j].as_ref().iter()) {
                    let e = a - b;
                    s += e * e;
                }
                s
            };
            dist2[i][j] = d2;
            dist2[j][i] = d2;
        }
    }
    let mut best = 0usize;
    let mut best_score = f64::INFINITY;
    for i in 0..n {
        let mut ds: Vec<f64> = (0..n).filter(|&j| j != i).map(|j| dist2[i][j]).collect();
        ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let score: f64 = ds.iter().take(k).sum();
        if score < best_score {
            best_score = score;
            best = i;
        }
    }
    best
}

fn coordinate_median<G: AsRef<[f64]>>(grads: &[G]) -> Vec<f64> {
    let n = grads.len();
    let d = grads[0].as_ref().len();
    let mut out = vec![0.0; d];
    let mut col = vec![0.0; n];
    for c in 0..d {
        for (i, g) in grads.iter().enumerate() {
            col[i] = g.as_ref()[c];
        }
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out[c] = if n % 2 == 1 { col[n / 2] } else { 0.5 * (col[n / 2 - 1] + col[n / 2]) };
    }
    out
}

fn trimmed_mean<G: AsRef<[f64]>>(grads: &[G], f: usize) -> Vec<f64> {
    let n = grads.len();
    assert!(2 * f < n, "trimmed mean needs 2f < n");
    let d = grads[0].as_ref().len();
    let keep = n - 2 * f;
    let mut out = vec![0.0; d];
    let mut col = vec![0.0; n];
    for c in 0..d {
        for (i, g) in grads.iter().enumerate() {
            col[i] = g.as_ref()[c];
        }
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out[c] = col[f..n - f].iter().sum::<f64>() / keep as f64;
    }
    out
}

/// Fused CGC-sum: computes `Σ ĝ_j` and the clipped set without
/// materializing the filtered copies (saves two O(n·d) clones on the
/// server's per-round hot path — see EXPERIMENTS.md §Perf).
pub fn cgc_sum_fused<G: AsRef<[f64]>>(grads: &[G], f: usize) -> (Vec<f64>, Vec<usize>) {
    let n = grads.len();
    assert!(f < n, "need f < n");
    let d = grads[0].as_ref().len();
    let mut out = vec![0.0; d];
    if f == 0 {
        for g in grads {
            linalg::axpy(1.0, g.as_ref(), &mut out);
        }
        return (out, Vec::new());
    }
    let norms: Vec<f64> = grads.iter().map(|g| norm(g.as_ref())).collect();
    let (scales, clipped) = cgc_scales(&norms, f);
    for (g, &s) in grads.iter().zip(scales.iter()) {
        linalg::axpy(s, g.as_ref(), &mut out);
    }
    (out, clipped)
}

/// Aggregate reconstructed gradients into the update direction `g^t`
/// (sum-equivalent scaling — see the module docs).
pub fn aggregate<G: AsRef<[f64]>>(agg: Aggregator, grads: &[G], f: usize) -> Vec<f64> {
    let n = grads.len();
    assert!(n > 0);
    match agg {
        Aggregator::CgcSum => cgc_sum_fused(grads, f).0,
        Aggregator::Mean => {
            let mut out = vec![0.0; grads[0].as_ref().len()];
            for g in grads {
                linalg::axpy(1.0, g.as_ref(), &mut out);
            }
            out
        }
        // In-place scaling on the per-round path: the winner/statistic
        // vector is already owned, so ×n costs zero extra allocations.
        Aggregator::Krum => {
            let mut out = grads[krum_select(grads, f)].as_ref().to_vec();
            linalg::scale_mut(n as f64, &mut out);
            out
        }
        Aggregator::CoordMedian => {
            let mut out = coordinate_median(grads);
            linalg::scale_mut(n as f64, &mut out);
            out
        }
        Aggregator::TrimmedMean => {
            let mut out = trimmed_mean(grads, f);
            linalg::scale_mut(n as f64, &mut out);
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[f64]) -> Vec<f64> {
        xs.to_vec()
    }

    #[test]
    fn cgc_clips_only_top_f_norms() {
        let grads = vec![v(&[1.0, 0.0]), v(&[0.0, 2.0]), v(&[0.0, 10.0]), v(&[100.0, 0.0])];
        let out = cgc_filter(&grads, 2);
        // Sorted norms: 1, 2, 10, 100; threshold = 2 (index n-f-1 = 1).
        assert_eq!(out[0], v(&[1.0, 0.0]));
        assert_eq!(out[1], v(&[0.0, 2.0]));
        assert!((norm(&out[2]) - 2.0).abs() < 1e-12);
        assert!((norm(&out[3]) - 2.0).abs() < 1e-12);
        // Directions preserved.
        assert!(out[2][1] > 0.0 && out[2][0] == 0.0);
        assert!(out[3][0] > 0.0 && out[3][1] == 0.0);
    }

    #[test]
    fn scales_agree_with_filter_report() {
        let grads = vec![v(&[1.0, 0.0]), v(&[0.0, 2.0]), v(&[0.0, 10.0]), v(&[100.0, 0.0])];
        let norms: Vec<f64> = grads.iter().map(|g| norm(g)).collect();
        let (scales, clipped) = cgc_scales(&norms, 2);
        assert_eq!(clipped, vec![2, 3]);
        assert_eq!(scales[0], 1.0);
        assert_eq!(scales[1], 1.0);
        assert!((scales[2] - 0.2).abs() < 1e-12);
        assert!((scales[3] - 0.02).abs() < 1e-12);
        // The filter's clipped set is the same rule.
        let (_, report_clipped) = cgc_filter_report(&grads, 2);
        assert_eq!(clipped, report_clipped);
    }

    #[test]
    fn cgc_f_zero_is_identity() {
        let grads = vec![v(&[3.0]), v(&[-5.0])];
        assert_eq!(cgc_filter(&grads, 0), grads);
    }

    #[test]
    fn cgc_norm_invariant_all_le_threshold() {
        // Post-filter, every norm ≤ the (n−f)-th pre-filter norm.
        let mut rng = crate::rng::Rng::new(1);
        for _ in 0..20 {
            let n = 3 + rng.range(0, 8);
            let f = rng.range(0, (n - 1) / 2 + 1);
            let grads: Vec<Vec<f64>> =
                (0..n).map(|_| crate::linalg::scale(rng.uniform() * 10.0, &rng.unit_vector(5))).collect();
            let mut norms: Vec<f64> = grads.iter().map(|g| norm(g)).collect();
            norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let thr = norms[n - f - 1];
            for g in cgc_filter(&grads, f) {
                assert!(norm(&g) <= thr * (1.0 + 1e-12));
            }
        }
    }

    #[test]
    fn cgc_zero_vectors_pass_through() {
        let grads = vec![v(&[0.0, 0.0]), v(&[1.0, 0.0]), v(&[0.0, 3.0])];
        let out = cgc_filter(&grads, 1);
        assert_eq!(out[0], v(&[0.0, 0.0]));
        assert_eq!(out[1], v(&[1.0, 0.0]));
        assert!((norm(&out[2]) - 1.0).abs() < 1e-12); // clipped to threshold 1
    }

    #[test]
    fn mean_is_plain_sum() {
        let grads = vec![v(&[1.0, 2.0]), v(&[3.0, -2.0])];
        assert_eq!(aggregate(Aggregator::Mean, &grads, 0), v(&[4.0, 0.0]));
    }

    #[test]
    fn krum_picks_the_cluster_not_the_outlier() {
        // 4 similar gradients + 1 far outlier; Krum must pick a cluster
        // member.
        let grads = vec![
            v(&[1.0, 1.0]),
            v(&[1.1, 0.9]),
            v(&[0.9, 1.1]),
            v(&[1.0, 1.05]),
            v(&[100.0, -100.0]),
        ];
        let out = aggregate(Aggregator::Krum, &grads, 1);
        // Scaled by n = 5: each coordinate near 5.
        assert!(out[0] > 4.0 && out[0] < 6.0, "{out:?}");
        assert!(out[1] > 4.0 && out[1] < 6.0, "{out:?}");
    }

    #[test]
    fn median_resists_extreme_coordinates() {
        let grads = vec![v(&[1.0]), v(&[2.0]), v(&[1e9])];
        let out = aggregate(Aggregator::CoordMedian, &grads, 1);
        assert_eq!(out, v(&[6.0])); // 3 × median(1, 2, 1e9) = 3·2
    }

    #[test]
    fn trimmed_mean_drops_tails() {
        let grads = vec![v(&[-1e9]), v(&[1.0]), v(&[2.0]), v(&[3.0]), v(&[1e9])];
        let out = aggregate(Aggregator::TrimmedMean, &grads, 1);
        assert_eq!(out, v(&[10.0])); // 5 × mean(1,2,3) = 5·2
    }

    #[test]
    fn parse_roundtrip() {
        for a in Aggregator::all() {
            assert_eq!(Aggregator::parse(a.name()), Some(a));
        }
        assert_eq!(Aggregator::parse("nope"), None);
    }

    #[test]
    fn cgc_sum_bounds_byzantine_influence() {
        // With the filter, a huge Byzantine gradient contributes at most the
        // (n−f)-th honest norm.
        let honest = vec![v(&[1.0, 0.0]), v(&[0.9, 0.1]), v(&[1.1, -0.1])];
        let mut grads = honest.clone();
        grads.push(v(&[-1e12, 1e12]));
        let out = aggregate(Aggregator::CgcSum, &grads, 1);
        let honest_sum: Vec<f64> =
            honest.iter().fold(vec![0.0, 0.0], |acc, g| crate::linalg::add(&acc, g));
        let dev = crate::linalg::dist(&out, &honest_sum);
        // Deviation bounded by the clip threshold (max honest norm ≈ 1.1).
        assert!(dev <= 1.2, "deviation {dev}");
    }
}
