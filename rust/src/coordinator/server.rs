//! [`ParameterServer`] — the fault-free central server of the paper.
//!
//! Per round it maintains the vector `G` of reconstructed gradients
//! (`g̃_j`), initialised to `⊥`. On a raw frame it stores the vector; on an
//! echo `(k, x, I)` it verifies that every referenced slot has a stored
//! gradient — the reliable-broadcast property makes a dangling reference
//! *proof* of Byzantine behaviour (§3, server steps) — and otherwise
//! reconstructs `g̃_j = k·A_I·x`. Malformed echoes (arity mismatch,
//! non-finite values, self/future references) are Byzantine by the same
//! argument. Exposed workers contribute `0⃗`.
//!
//! **Lossy channels weaken the exposure argument.** Under an unreliable
//! radio ([`crate::radio::channel`]) a silent slot may be an erased frame
//! and an echo reference to an *elapsed* slot may point at a frame the
//! *server* missed — neither proves Byzantine behaviour. In lossy mode
//! ([`ParameterServer::set_lossy`]) those two cases degrade to
//! [`SlotOutcome::Lost`]: the slot contributes `0⃗` *this round* but the
//! worker is **not** added to the exposed set. Content-provable
//! malformations (non-finite values, arity mismatches, self references,
//! unsorted id sets, and references to slots that have not even elapsed
//! — no erasure explains overhearing a frame that was never on air)
//! still expose — erasures drop frames, they never rewrite them.

use super::aggregators::{aggregate, cgc_scales, Aggregator};
use crate::linalg;
use crate::wire::Payload;
use std::collections::BTreeSet;

/// Per-worker norms `‖g̃_j‖`, fanned across up to `threads` scoped threads.
/// Each norm is an independent O(d) reduction computed exactly as the
/// serial [`crate::linalg::norm`], so the partition cannot change a bit.
fn parallel_norms(grads: &[&[f64]], threads: usize) -> Vec<f64> {
    let mut jobs: Vec<(usize, f64)> = (0..grads.len()).map(|i| (i, 0.0)).collect();
    crate::par::scoped_for_each(&mut jobs, threads, |job| {
        job.1 = crate::linalg::norm(grads[job.0]);
    });
    jobs.into_iter().map(|(_, n)| n).collect()
}

/// Parallel fused CGC sum (the threaded counterpart of
/// [`super::aggregators::cgc_sum_fused`], sharing its
/// [`cgc_scales`] clip rule), parallel over **workers** for the
/// O(n·d) norm pass and over **coordinates** for the O(n·d) weighted sum.
///
/// Bit-identical to the serial fallback at any thread count: every norm is
/// an independent reduction, and each thread owns a disjoint coordinate
/// range in which it accumulates worker contributions in exactly the
/// serial order `j = 0..n` (`out[c] += scale_j · g_j[c]`, same operation,
/// same order). Pinned by `parallel_cgc_aggregation_bitwise_matches_serial`
/// below and the engine-level tests in `rust/tests/determinism.rs`.
fn cgc_sum_fused_refs(
    grads: &[&[f64]],
    f: usize,
    d: usize,
    threads: usize,
) -> (Vec<f64>, Vec<usize>) {
    // f = 0 needs no norms at all; scales degenerate to all-ones.
    let (scales, clipped) = if f == 0 {
        (vec![1.0; grads.len()], Vec::new())
    } else {
        let norms = parallel_norms(grads, threads);
        cgc_scales(&norms, f)
    };
    let mut out = vec![0.0; d];
    crate::par::scoped_chunks(&mut out, threads, |off, chunk| {
        for (g, &s) in grads.iter().zip(scales.iter()) {
            let seg = &g[off..off + chunk.len()];
            for (o, &x) in chunk.iter_mut().zip(seg.iter()) {
                *o += s * x;
            }
        }
    });
    (out, clipped)
}

/// What the server concluded about one slot (diagnostics / tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotOutcome {
    /// Raw gradient stored as-is.
    Raw,
    /// Echo verified and reconstructed.
    EchoReconstructed,
    /// Echo exposed the sender as Byzantine (stored 0⃗).
    EchoExposed,
    /// No frame in the slot (synchrony ⇒ sender is faulty; stored 0⃗).
    Silent,
    /// Lossy-channel casualty: the frame (or an echo's referenced basis)
    /// never reached the server within the retransmit budget. Stored 0⃗
    /// for this round, but **no exposure** — channel loss is not proof of
    /// Byzantine behaviour.
    Lost,
    /// The sender's sharded uplink reconstructed to *different content*
    /// at the server and at honest overhearers (hash commitments differ).
    /// Content-provable equivocation — exposed under any channel, lossy
    /// or not (stored 0⃗).
    Equivocated,
}

/// Verdict of the echo validity check.
enum EchoCheck {
    Ok,
    /// Content-provable malformation — Byzantine under any channel.
    Malformed,
    /// Structurally valid but references a slot the server has no stored
    /// gradient for — proof of lying under a reliable channel, a possible
    /// erasure under a lossy one.
    MissingRef,
}

/// The central parameter server.
pub struct ParameterServer {
    n: usize,
    f: usize,
    /// The clip budget actually applied this round. Equals `f` except
    /// under an epoch-keyed churn roster, where the round engine
    /// re-derives it from the round's *active* membership
    /// (`f' = min(f, ⌈active−1⌉/2)`) so the CGC threshold keeps its
    /// `2f' < active` guarantee when workers are absent.
    round_f: usize,
    d: usize,
    agg: Aggregator,
    /// `G` — reconstructed gradients of the current round (`None` = ⊥).
    g: Vec<Option<Vec<f64>>>,
    outcomes: Vec<Option<SlotOutcome>>,
    /// Workers proven Byzantine in any round so far.
    exposed: BTreeSet<usize>,
    /// Zeno-style suspicion: how many rounds each worker's gradient was
    /// clipped by the CGC filter. Honest workers get clipped only when
    /// their stochastic norm lands in the top f; a norm-inflating
    /// Byzantine is clipped every round, so the counter separates them
    /// sharply over time (diagnostic only — the algorithm's guarantees do
    /// not depend on it).
    clip_counts: Vec<u64>,
    /// Gradients clipped in the most recent aggregation round (the
    /// per-round filter-decision count the trace pipeline records).
    last_clipped: usize,
    rounds_aggregated: u64,
    /// Worker threads for the aggregation phase (norm pass + CGC sum).
    /// `1` = serial; results are bit-identical at any setting.
    threads: usize,
    /// Lossy-channel mode: silence and dangling echo references become
    /// [`SlotOutcome::Lost`] instead of exposures (see the module docs).
    lossy: bool,
}

impl ParameterServer {
    pub fn new(n: usize, f: usize, d: usize, agg: Aggregator) -> Self {
        assert!(n >= 1 && f < n, "need f < n");
        Self {
            n,
            f,
            round_f: f,
            d,
            agg,
            g: vec![None; n],
            outcomes: vec![None; n],
            exposed: BTreeSet::new(),
            clip_counts: vec![0; n],
            last_clipped: 0,
            rounds_aggregated: 0,
            threads: 1,
            lossy: false,
        }
    }

    /// Switch the server's inference regime to an unreliable channel (the
    /// round engine wires this to `ExperimentConfig::channel`): missing
    /// frames stop being proof of Byzantine behaviour.
    pub fn set_lossy(&mut self, lossy: bool) {
        self.lossy = lossy;
    }

    /// Set the aggregation-phase thread count (a pure throughput knob —
    /// see [`cgc_sum_fused_refs`]). The round engine wires this to
    /// [`crate::config::ExperimentConfig::threads`].
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn f(&self) -> usize {
        self.f
    }

    /// Re-derive the clip budget for the current round's membership (the
    /// churn roster calls this before the communication phase; without
    /// churn it never moves off `f`, keeping the pre-roster bytes).
    pub fn set_round_f(&mut self, round_f: usize) {
        assert!(round_f <= self.f, "the roster can only shrink the clip budget");
        self.round_f = round_f;
    }

    /// The clip budget applied by [`Self::aggregate_tracked`] this round.
    pub fn round_f(&self) -> usize {
        self.round_f
    }

    pub fn aggregator(&self) -> Aggregator {
        self.agg
    }

    /// Reset `G` to all-⊥ (start of the communication phase).
    pub fn begin_round(&mut self) {
        for gi in self.g.iter_mut() {
            *gi = None;
        }
        for o in self.outcomes.iter_mut() {
            *o = None;
        }
    }

    fn expose(&mut self, j: usize, outcome: SlotOutcome) {
        self.exposed.insert(j);
        self.g[j] = Some(vec![0.0; self.d]);
        self.outcomes[j] = Some(outcome);
    }

    fn mark_lost(&mut self, j: usize) {
        self.g[j] = Some(vec![0.0; self.d]);
        self.outcomes[j] = Some(SlotOutcome::Lost);
    }

    /// A frame the channel erased entirely (every attempt within the
    /// retransmit budget missed the server): the slot contributes `0⃗`
    /// this round, with no exposure.
    pub fn on_lost(&mut self, j: usize) {
        assert!(j < self.n);
        assert!(self.g[j].is_none(), "slot {j} delivered twice");
        self.mark_lost(j);
    }

    /// Worker `j`'s sharded uplink reconstructed to different content at
    /// the server and at an honest overhearer — the hash commitments
    /// disagree, which is content-provable equivocation. Unlike loss or
    /// silence this exposes **even in lossy mode**: erasures can hide a
    /// frame, but they cannot manufacture two consistent reconstructions
    /// with mismatched digests.
    pub fn on_equivocation(&mut self, j: usize) -> SlotOutcome {
        assert!(j < self.n);
        assert!(self.g[j].is_none(), "slot {j} delivered twice");
        self.expose(j, SlotOutcome::Equivocated);
        SlotOutcome::Equivocated
    }

    /// Does slot `i` hold a gradient an echo may reference? A `Lost` slot
    /// does not: its stored `0⃗` is a placeholder for the aggregation, not
    /// the frame the echoing worker actually overheard — reconstructing
    /// against it would silently corrupt the echo. (Exposed slots also
    /// store `0⃗`, but honest workers can never have such frames in their
    /// span: every exposable frame is one listeners reject too.)
    fn slot_stored(&self, i: usize) -> bool {
        self.g[i].is_some() && self.outcomes[i] != Some(SlotOutcome::Lost)
    }

    /// Are all of `ids` slots whose gradient the server has stored (and
    /// can honour as echo basis columns)? The round engine uses this as
    /// the NACK check behind the honest worker's echo→raw fallback.
    pub fn echo_refs_stored(&self, ids: &[usize]) -> bool {
        ids.iter().all(|&i| i < self.n && self.slot_stored(i))
    }

    /// Process the frame transmitted in worker `j`'s slot.
    pub fn on_frame(&mut self, j: usize, payload: &Payload) -> SlotOutcome {
        assert!(j < self.n);
        assert!(self.g[j].is_none(), "slot {j} delivered twice");
        match payload {
            Payload::Raw(grad) => {
                if grad.len() != self.d || grad.iter().any(|v| !v.is_finite()) {
                    // A malformed "gradient" can only come from a Byzantine
                    // worker; it is treated like an extreme gradient and
                    // zeroed (the CGC filter would clip it anyway, but a
                    // wrong-dimension vector cannot even be summed).
                    self.expose(j, SlotOutcome::EchoExposed);
                    return SlotOutcome::EchoExposed;
                }
                self.g[j] = Some(grad.clone());
                self.outcomes[j] = Some(SlotOutcome::Raw);
                SlotOutcome::Raw
            }
            Payload::Echo { k, coeffs, ids } => {
                match self.validate_echo(j, *k, coeffs, ids) {
                    EchoCheck::Ok => {}
                    EchoCheck::Malformed => {
                        self.expose(j, SlotOutcome::EchoExposed);
                        return SlotOutcome::EchoExposed;
                    }
                    EchoCheck::MissingRef => {
                        // Reliable channel: only a liar references an
                        // undelivered slot. Lossy channel: the server may
                        // simply have missed that frame.
                        if self.lossy {
                            self.mark_lost(j);
                            return SlotOutcome::Lost;
                        }
                        self.expose(j, SlotOutcome::EchoExposed);
                        return SlotOutcome::EchoExposed;
                    }
                }
                // g̃_j = k · A_I · x over the *stored* gradients (which for
                // echo senders are themselves reconstructions).
                let cols: Vec<&Vec<f64>> =
                    ids.iter().map(|&i| self.g[i].as_ref().unwrap()).collect();
                let mut rec = vec![0.0; self.d];
                for (c, &xi) in cols.iter().zip(coeffs.iter()) {
                    linalg::axpy(xi, c, &mut rec);
                }
                linalg::scale_mut(*k, &mut rec);
                if rec.iter().any(|v| !v.is_finite()) {
                    self.expose(j, SlotOutcome::EchoExposed);
                    return SlotOutcome::EchoExposed;
                }
                self.g[j] = Some(rec);
                self.outcomes[j] = Some(SlotOutcome::EchoReconstructed);
                SlotOutcome::EchoReconstructed
            }
            Payload::SparseRaw { dim, idx, vals } => {
                // Top-k baseline frame: densify and treat as a raw gradient.
                if *dim != self.d
                    || idx.len() != vals.len()
                    || vals.iter().any(|v| !v.is_finite())
                    || idx.iter().any(|&i| i as usize >= self.d)
                {
                    self.expose(j, SlotOutcome::EchoExposed);
                    return SlotOutcome::EchoExposed;
                }
                self.g[j] = Some(crate::wire::densify(self.d, idx, vals));
                self.outcomes[j] = Some(SlotOutcome::Raw);
                SlotOutcome::Raw
            }
            Payload::Param(_) => {
                // Only the server transmits parameters; a worker sending one
                // is Byzantine.
                self.expose(j, SlotOutcome::EchoExposed);
                SlotOutcome::EchoExposed
            }
        }
    }

    /// A silent slot. Under the reliable channel the synchronous model
    /// lets the server conclude the worker is faulty (§2.1); under a
    /// lossy one, silence is indistinguishable from a total erasure and
    /// only costs the worker its round.
    pub fn on_silence(&mut self, j: usize) {
        assert!(j < self.n);
        if self.lossy {
            self.mark_lost(j);
        } else {
            self.expose(j, SlotOutcome::Silent);
        }
    }

    fn validate_echo(&self, j: usize, k: f64, coeffs: &[f64], ids: &[usize]) -> EchoCheck {
        if !k.is_finite() || k < 0.0 {
            return EchoCheck::Malformed;
        }
        if coeffs.is_empty() || coeffs.len() != ids.len() {
            return EchoCheck::Malformed;
        }
        if coeffs.iter().any(|c| !c.is_finite()) {
            return EchoCheck::Malformed;
        }
        let mut prev: Option<usize> = None;
        let mut missing = false;
        for &i in ids {
            // Self-references and out-of-range ids violate the message
            // format outright, as do duplicate / unsorted ids (I is an
            // ascending set, line 20) — provable under any channel.
            if i >= self.n || i == j {
                return EchoCheck::Malformed;
            }
            if let Some(p) = prev {
                if i <= p {
                    return EchoCheck::Malformed;
                }
            }
            prev = Some(i);
            // A reference to a slot that has not even elapsed (G[i] = ⊥:
            // every *elapsed* slot is filled — raw/echo/exposed/Lost all
            // store something) is proof of lying under ANY channel: no
            // erasure explains overhearing a frame that was never on
            // air.
            if self.g[i].is_none() {
                return EchoCheck::Malformed;
            }
            // A reference to an elapsed slot whose frame the server
            // itself lost is the genuinely ambiguous case: proof of
            // lying under the reliable channel, possibly the server's
            // own erasure under a lossy one.
            if self.outcomes[i] == Some(SlotOutcome::Lost) {
                missing = true;
            }
        }
        if missing {
            EchoCheck::MissingRef
        } else {
            EchoCheck::Ok
        }
    }

    /// Gradients reconstructed this round, as borrowed slices — no O(n·d)
    /// clone (⊥ slots panic — call only after all slots were processed).
    pub fn gradients(&self) -> Vec<&[f64]> {
        self.g
            .iter()
            .enumerate()
            .map(|(j, g)| g.as_deref().unwrap_or_else(|| panic!("slot {j} still ⊥")))
            .collect()
    }

    /// The stored gradient of one slot, if present (test access).
    pub fn stored(&self, j: usize) -> Option<&Vec<f64>> {
        self.g[j].as_ref()
    }

    pub fn outcome(&self, j: usize) -> Option<SlotOutcome> {
        self.outcomes[j]
    }

    /// Workers proven Byzantine so far (cumulative across rounds).
    pub fn exposed(&self) -> &BTreeSet<usize> {
        &self.exposed
    }

    /// Aggregation phase: apply the configured rule and return `g^t`.
    pub fn aggregate(&self) -> Vec<f64> {
        let grads = self.gradients();
        aggregate(self.agg, &grads, self.round_f)
    }

    /// Aggregate and update the suspicion counters (the round engine's
    /// entry point; [`Self::aggregate`] is the pure variant).
    pub fn aggregate_tracked(&mut self) -> Vec<f64> {
        self.rounds_aggregated += 1;
        if self.agg == Aggregator::CgcSum {
            // Fused path: no O(n·d) clone of G, no filtered copies; the
            // norm pass and the weighted sum run across the thread pool.
            let (out, clipped) = {
                let grads = self.gradients();
                cgc_sum_fused_refs(&grads, self.round_f, self.d, self.threads)
            };
            self.last_clipped = clipped.len();
            for j in clipped {
                self.clip_counts[j] += 1;
            }
            out
        } else {
            self.last_clipped = 0;
            let grads = self.gradients();
            aggregate(self.agg, &grads, self.round_f)
        }
    }

    /// Gradients clipped by the CGC filter in the most recent
    /// [`Self::aggregate_tracked`] round (0 under non-CGC rules).
    pub fn clipped_last_round(&self) -> usize {
        self.last_clipped
    }

    /// Suspicion score per worker: fraction of aggregated rounds in which
    /// it was clipped (1.0 for exposed workers).
    pub fn suspicion(&self) -> Vec<f64> {
        (0..self.n)
            .map(|j| {
                if self.exposed.contains(&j) {
                    1.0
                } else if self.rounds_aggregated == 0 {
                    0.0
                } else {
                    self.clip_counts[j] as f64 / self.rounds_aggregated as f64
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn server(n: usize, f: usize, d: usize) -> ParameterServer {
        let mut s = ParameterServer::new(n, f, d, Aggregator::CgcSum);
        s.begin_round();
        s
    }

    #[test]
    fn raw_frames_stored_verbatim() {
        let mut s = server(3, 0, 2);
        assert_eq!(s.on_frame(0, &Payload::Raw(vec![1.0, 2.0])), SlotOutcome::Raw);
        assert_eq!(s.stored(0), Some(&vec![1.0, 2.0]));
    }

    #[test]
    fn equivocation_exposes_even_in_lossy_mode() {
        let mut s = server(3, 1, 2);
        s.set_lossy(true);
        assert_eq!(s.on_equivocation(0), SlotOutcome::Equivocated);
        assert!(s.exposed().contains(&0), "content-proof beats channel deniability");
        assert_eq!(s.stored(0), Some(&vec![0.0, 0.0]));
        // Plain loss on the very same lossy server still never exposes.
        s.on_lost(1);
        assert_eq!(s.outcome(1), Some(SlotOutcome::Lost));
        assert!(!s.exposed().contains(&1));
    }

    #[test]
    fn echo_reconstructs_k_aix() {
        let mut s = server(3, 0, 3);
        s.on_frame(0, &Payload::Raw(vec![1.0, 0.0, 0.0]));
        s.on_frame(1, &Payload::Raw(vec![0.0, 1.0, 0.0]));
        let out = s.on_frame(
            2,
            &Payload::Echo { k: 2.0, coeffs: vec![3.0, 4.0], ids: vec![0, 1] },
        );
        assert_eq!(out, SlotOutcome::EchoReconstructed);
        assert_eq!(s.stored(2), Some(&vec![6.0, 8.0, 0.0]));
    }

    #[test]
    fn dangling_reference_exposes_byzantine() {
        let mut s = server(4, 1, 2);
        s.on_frame(0, &Payload::Raw(vec![1.0, 0.0]));
        // Worker 1 references worker 2, whose slot has not happened: only a
        // liar can do that (reliable broadcast ⇒ it knows slot order).
        let out =
            s.on_frame(1, &Payload::Echo { k: 1.0, coeffs: vec![1.0], ids: vec![2] });
        assert_eq!(out, SlotOutcome::EchoExposed);
        assert!(s.exposed().contains(&1));
        assert_eq!(s.stored(1), Some(&vec![0.0, 0.0]));
    }

    #[test]
    fn self_reference_and_duplicates_exposed() {
        let mut s = server(4, 1, 2);
        s.on_frame(0, &Payload::Raw(vec![1.0, 0.0]));
        let self_ref = Payload::Echo { k: 1.0, coeffs: vec![1.0], ids: vec![1] };
        assert_eq!(s.on_frame(1, &self_ref), SlotOutcome::EchoExposed);
        let dup = Payload::Echo { k: 1.0, coeffs: vec![1.0, 1.0], ids: vec![0, 0] };
        assert_eq!(s.on_frame(2, &dup), SlotOutcome::EchoExposed);
    }

    #[test]
    fn malformed_echoes_exposed() {
        let mut s = server(5, 1, 2);
        s.on_frame(0, &Payload::Raw(vec![1.0, 0.0]));
        let bad_k = Payload::Echo { k: f64::NAN, coeffs: vec![1.0], ids: vec![0] };
        assert_eq!(s.on_frame(1, &bad_k), SlotOutcome::EchoExposed);
        let neg_k = Payload::Echo { k: -2.0, coeffs: vec![1.0], ids: vec![0] };
        assert_eq!(s.on_frame(2, &neg_k), SlotOutcome::EchoExposed);
        let arity = Payload::Echo { k: 1.0, coeffs: vec![1.0, 2.0], ids: vec![0] };
        assert_eq!(s.on_frame(3, &arity), SlotOutcome::EchoExposed);
        let empty = Payload::Echo { k: 1.0, coeffs: vec![], ids: vec![] };
        assert_eq!(s.on_frame(4, &empty), SlotOutcome::EchoExposed);
    }

    #[test]
    fn silent_slot_is_faulty() {
        let mut s = server(2, 1, 2);
        s.on_silence(0);
        assert!(s.exposed().contains(&0));
        assert_eq!(s.outcome(0), Some(SlotOutcome::Silent));
        assert_eq!(s.stored(0), Some(&vec![0.0, 0.0]));
    }

    #[test]
    fn wrong_dim_or_nonfinite_raw_exposed() {
        let mut s = server(3, 1, 3);
        assert_eq!(s.on_frame(0, &Payload::Raw(vec![1.0])), SlotOutcome::EchoExposed);
        assert_eq!(
            s.on_frame(1, &Payload::Raw(vec![f64::NAN, 0.0, 0.0])),
            SlotOutcome::EchoExposed
        );
    }

    #[test]
    fn echo_chain_through_prior_echo() {
        // Worker 2 echoes {0}; worker 3 echoes {0, 2} — the server must use
        // the *reconstructed* g̃_2 as a column.
        let mut s = server(4, 0, 2);
        s.on_frame(0, &Payload::Raw(vec![2.0, 0.0]));
        s.on_frame(1, &Payload::Raw(vec![0.0, 1.0]));
        s.on_frame(2, &Payload::Echo { k: 1.0, coeffs: vec![0.5], ids: vec![0] });
        assert_eq!(s.stored(2), Some(&vec![1.0, 0.0]));
        s.on_frame(
            3,
            &Payload::Echo { k: 2.0, coeffs: vec![1.0, 1.0], ids: vec![1, 2] },
        );
        assert_eq!(s.stored(3), Some(&vec![2.0, 2.0]));
    }

    #[test]
    fn round_trip_matches_worker_reconstruction() {
        // End-to-end invariant: for an honest worker the server's g̃_j is
        // the worker's echo gradient rescaled to ‖g_j‖.
        let mut rng = Rng::new(3);
        let d = 25;
        let mut s = server(3, 0, d);
        let c0 = rng.normal_vec(d);
        let c1 = rng.normal_vec(d);
        s.on_frame(0, &Payload::Raw(c0.clone()));
        s.on_frame(1, &Payload::Raw(c1.clone()));

        let mut w = crate::worker::EchoWorker::new(2, d, 0.9, 1e-9);
        // Gradient near the span ⇒ echo.
        let mut g = crate::linalg::add(&c0, &c1);
        for gi in g.iter_mut() {
            *gi += 0.01 * rng.normal();
        }
        w.begin_round(g.clone());
        w.overhear(0, &Payload::Raw(c0.clone()));
        w.overhear(1, &Payload::Raw(c1.clone()));
        let frame = w.transmit();
        assert!(frame.is_echo(), "expected echo");
        s.on_frame(2, &frame);
        let rec = s.stored(2).unwrap();
        // ‖g̃‖ = ‖g‖ (paper: a_j scaling preserves the norm).
        let gn = crate::linalg::norm(&g);
        assert!((crate::linalg::norm(rec) - gn).abs() < 1e-6 * gn);
        // And the deviation is bounded by roughly r within the span.
        assert!(crate::linalg::dist(rec, &g) <= 2.0 * 0.9 * gn);
    }

    #[test]
    fn parallel_cgc_aggregation_bitwise_matches_serial() {
        // Two servers fed identical frames — raw honest gradients, one
        // Byzantine-sized gradient (forces the clip path), one verified
        // echo, one silent slot — must aggregate to the same bits whether
        // the norm pass + CGC sum run serial or threaded. d is odd so the
        // coordinate chunking exercises a ragged tail.
        let mut rng = Rng::new(9);
        let (n, f, d) = (9usize, 2usize, 103usize);
        for threads in [2usize, 4, 8] {
            let mut rng_t = rng.split(threads as u64);
            let mut serial = ParameterServer::new(n, f, d, Aggregator::CgcSum);
            let mut par = ParameterServer::new(n, f, d, Aggregator::CgcSum);
            par.set_threads(threads);
            serial.begin_round();
            par.begin_round();
            for j in 0..n {
                if j == 4 {
                    serial.on_silence(j);
                    par.on_silence(j);
                    continue;
                }
                let payload = if j == 3 {
                    Payload::Raw(crate::linalg::scale(1e6, &rng_t.normal_vec(d)))
                } else if j == n - 1 {
                    Payload::Echo { k: 1.5, coeffs: vec![0.5, -0.25], ids: vec![0, 1] }
                } else {
                    Payload::Raw(rng_t.normal_vec(d))
                };
                assert_eq!(serial.on_frame(j, &payload), par.on_frame(j, &payload));
            }
            let a = serial.aggregate_tracked();
            let b = par.aggregate_tracked();
            let ab: Vec<u64> = a.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u64> = b.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "threads={threads}");
            assert_eq!(serial.suspicion(), par.suspicion(), "threads={threads}");
        }
    }

    #[test]
    fn borrowed_gradients_expose_stored_slots() {
        let mut s = server(3, 0, 2);
        s.on_frame(0, &Payload::Raw(vec![1.0, 2.0]));
        s.on_frame(1, &Payload::Raw(vec![3.0, 4.0]));
        s.on_frame(2, &Payload::Raw(vec![5.0, 6.0]));
        let grads = s.gradients();
        assert_eq!(grads.len(), 3);
        assert_eq!(grads[1], &[3.0, 4.0][..]);
        // The non-fused rules consume the same borrows without cloning.
        let sum = aggregate(Aggregator::Mean, &grads, 0);
        assert_eq!(sum, vec![9.0, 12.0]);
    }

    #[test]
    #[should_panic(expected = "delivered twice")]
    fn double_delivery_panics() {
        let mut s = server(2, 0, 1);
        s.on_frame(0, &Payload::Raw(vec![1.0]));
        s.on_frame(0, &Payload::Raw(vec![1.0]));
    }

    #[test]
    fn lossy_mode_does_not_expose_missing_frames() {
        let mut s = ParameterServer::new(4, 1, 2, Aggregator::CgcSum);
        s.set_lossy(true);
        s.begin_round();
        // A frame the channel erased entirely.
        s.on_lost(0);
        assert_eq!(s.outcome(0), Some(SlotOutcome::Lost));
        assert_eq!(s.stored(0), Some(&vec![0.0, 0.0]));
        // Silence is indistinguishable from loss.
        s.on_silence(1);
        assert_eq!(s.outcome(1), Some(SlotOutcome::Lost));
        s.on_frame(2, &Payload::Raw(vec![1.0, 2.0]));
        // A dangling reference may be the server's own erasure (slot 0
        // was lost): zero the slot, expose nobody.
        let echo = Payload::Echo { k: 1.0, coeffs: vec![1.0, 1.0], ids: vec![0, 2] };
        assert_eq!(s.on_frame(3, &echo), SlotOutcome::Lost);
        assert!(s.exposed().is_empty(), "channel loss must never expose");
        // Aggregation still works over the zero-filled slots.
        let g = s.aggregate_tracked();
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn lossy_mode_still_exposes_provable_malformations() {
        let mut s = ParameterServer::new(5, 1, 2, Aggregator::CgcSum);
        s.set_lossy(true);
        s.begin_round();
        s.on_frame(0, &Payload::Raw(vec![1.0, 0.0]));
        // Self-reference: content-provable regardless of the channel.
        let self_ref = Payload::Echo { k: 1.0, coeffs: vec![1.0], ids: vec![1] };
        assert_eq!(s.on_frame(1, &self_ref), SlotOutcome::EchoExposed);
        // A reference to a slot that has not even elapsed (slot 4): no
        // erasure explains overhearing a frame that was never on air —
        // exposed even under a lossy channel.
        let future = Payload::Echo { k: 1.0, coeffs: vec![1.0, 1.0], ids: vec![0, 4] };
        assert_eq!(s.on_frame(2, &future), SlotOutcome::EchoExposed);
        let bad_k = Payload::Echo { k: f64::NAN, coeffs: vec![1.0], ids: vec![0] };
        assert_eq!(s.on_frame(3, &bad_k), SlotOutcome::EchoExposed);
        let dup = Payload::Echo { k: 1.0, coeffs: vec![1.0, 1.0], ids: vec![0, 0] };
        assert_eq!(s.on_frame(4, &dup), SlotOutcome::EchoExposed);
        assert_eq!(s.exposed().len(), 4);
    }

    #[test]
    fn echo_refs_stored_reflects_the_round_state() {
        let mut s = server(3, 0, 2);
        s.on_frame(0, &Payload::Raw(vec![1.0, 2.0]));
        assert!(s.echo_refs_stored(&[0]));
        assert!(!s.echo_refs_stored(&[0, 1]), "slot 1 not yet stored");
        assert!(!s.echo_refs_stored(&[7]), "out of range");
    }

    #[test]
    fn round_f_rederives_the_clip_budget() {
        // Same frames, shrunken round budget: with round_f = 0 the huge
        // gradient passes unclipped; at the configured f = 1 it is clipped.
        let frames = [vec![1.0, 0.0], vec![0.0, 2.0], vec![1e6, 0.0]];
        let mut full = server(3, 1, 2);
        let mut shrunk = server(3, 1, 2);
        shrunk.set_round_f(0);
        for (j, p) in frames.iter().enumerate() {
            full.on_frame(j, &Payload::Raw(p.clone()));
            shrunk.on_frame(j, &Payload::Raw(p.clone()));
        }
        assert_eq!(full.aggregate_tracked(), vec![3.0, 2.0]); // 1e6 clipped to 2
        assert_eq!(full.clipped_last_round(), 1);
        assert_eq!(shrunk.aggregate_tracked(), vec![1e6 + 1.0, 2.0]);
        assert_eq!(shrunk.clipped_last_round(), 0);
        assert_eq!(shrunk.f(), 1, "configured f untouched");
        assert_eq!(shrunk.round_f(), 0);
    }

    #[test]
    fn all_lost_round_aggregates_to_the_zero_update() {
        // A round where every worker is absent or late: every slot routes
        // through on_lost, the CGC threshold degenerates to 0, and the
        // update is exactly 0⃗ — no panic, no NaN, no exposure.
        let mut s = ParameterServer::new(4, 1, 3, Aggregator::CgcSum);
        s.set_lossy(true);
        s.begin_round();
        for j in 0..4 {
            s.on_lost(j);
        }
        let g = s.aggregate_tracked();
        assert_eq!(g, vec![0.0; 3]);
        assert!(g.iter().all(|v| v.is_finite()));
        assert!(s.exposed().is_empty(), "slow/absent is never Byzantine");
    }

    #[test]
    fn reliable_mode_unchanged_by_default() {
        // The pre-channel exposure semantics are the default.
        let mut s = server(2, 1, 2);
        s.on_silence(0);
        assert_eq!(s.outcome(0), Some(SlotOutcome::Silent));
        assert!(s.exposed().contains(&0));
    }
}
